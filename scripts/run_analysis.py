"""CI/dev wrapper for the static analyzer (`python -m repro.analysis`).

Adds the two things the raw module entry point leaves to the caller:

* puts ``src/`` on ``sys.path`` so the script runs from a bare checkout
  (no install, no PYTHONPATH juggling) — the same trick the benchmarks use;
* defaults ``--json`` to ``analysis/findings.json`` so CI always has an
  artifact to upload, pass/fail alike.

Usage:
    python scripts/run_analysis.py --check                 # the CI gate
    python scripts/run_analysis.py --update-baselines      # regenerate pins
    python scripts/run_analysis.py --check --placements sharded   # 8-dev leg
"""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))


def main() -> int:
    from repro.analysis.cli import run
    argv = sys.argv[1:]
    if not any(a == "--json" or a.startswith("--json=") for a in argv):
        argv += ["--json", os.path.join(REPO, "analysis", "findings.json")]
    return run(argv)


if __name__ == "__main__":
    sys.exit(main())
