"""Render the §Roofline baseline table from experiments/dryrun_results.json
into EXPERIMENTS.md (replaces the <!-- ROOFLINE_TABLE --> marker block)."""
import json
import re
import sys

RESULTS = "experiments/dryrun_results.json"
TARGET = "EXPERIMENTS.md"
MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    with open(RESULTS) as f:
        recs = json.load(f)
    rows = [r for r in recs if r.get("ok") and "pod" not in r["mesh"]
            and "+" not in r["program"]]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    skips = [r for r in recs if r.get("skipped")]

    lines = [MARK,
             "| arch | shape | program | compute_s | memory_s | collective_s "
             "| dominant | model_FLOPs | useful | args_GiB | temp_GiB |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        mem = r["memory"]
        args_gb = (mem.get("argument_bytes") or 0) / 2**30
        temp_gb = (mem.get("temp_bytes") or 0) / 2**30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['program']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | {rl['dominant']} "
            f"| {rl['model_flops']:.2e} | {rl['useful_ratio']:.3f} "
            f"| {args_gb:.1f} | {temp_gb:.1f} |")
    for r in skips:
        lines.append(f"| {r['arch']} | {r['shape']} | SKIPPED | | | | | | | | |")
    lines.append("")
    lines.append(f"({len(rows)} baseline pairs; args/temp GiB are whole-job "
                 "sizes from compiled.memory_analysis(), divide by 256 chips "
                 "for per-device.)")
    block = "\n".join(lines)

    with open(TARGET) as f:
        text = f.read()
    if MARK not in text:
        sys.exit(f"marker {MARK} not found")
    # replace from marker to the next section header
    pattern = re.escape(MARK) + r".*?(?=\n### |\n## )"
    new_text, n = re.subn(pattern, block + "\n", text, flags=re.S)
    if n == 0:
        new_text = text.replace(MARK, block)
    with open(TARGET, "w") as f:
        f.write(new_text)
    print(f"updated {TARGET} with {len(rows)} rows")


if __name__ == "__main__":
    main()
