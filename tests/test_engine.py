"""Batched cluster-parallel engine: equivalence with the sequential
reference oracle.

The contract (see ``core/engine.py``): on seeded runs the two engines must
select the same cluster every round, produce validation losses equal within
float tolerance, and report bit-identical CommMeter message counts — across
the honest case and all three message-level attacks, plus the param-tamper
handoff scenario and the SplitFed baseline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACTIVATION, BACKDOOR, GRAD_NOISE, GRAD_SCALE,
                        GRADIENT, HONEST, LABEL_FLIP, PARAM_TAMPER, REPLAY,
                        Attack, AttackVec, ClientThreat, ProtocolConfig,
                        ThreatModel, after_warmup, attack_vec, every_k, ramp,
                        run_pigeon, run_pigeon_plus, run_pigeon_sweep,
                        run_splitfed, run_vanilla_sl, stealth)
from repro.core.attacks import (attack_vec_for_clusters, flip_labels,
                                flip_labels_vec, tamper_activation,
                                tamper_activation_vec, tamper_gradient,
                                tamper_gradient_vec)
from repro.core.engine import onehot_select
from repro.core.split import client_update, client_update_vec


def assert_histories_equivalent(h_seq, h_bat, check_comm=True):
    assert len(h_seq.rounds) == len(h_bat.rounds)
    for rs, rb in zip(h_seq.rounds, h_bat.rounds):
        assert rs["clusters"] == rb["clusters"]
        assert rs["selected"] == rb["selected"], (rs["round"], rs, rb)
        assert rs["selected_honest"] == rb["selected_honest"]
        np.testing.assert_allclose(rs["val_losses"], rb["val_losses"],
                                   rtol=2e-5, atol=1e-6)
        if check_comm:
            assert rs["comm"] == rb["comm"]      # bit-identical float counts
        if "detections" in rs:
            assert rs["detections"] == rb["detections"]


ATTACK_CASES = [
    ("honest", set(), HONEST),
    ("label_flip", {1}, Attack(LABEL_FLIP)),
    ("activation", {1}, Attack(ACTIVATION)),
    ("gradient", {1}, Attack(GRADIENT)),
]


@pytest.mark.parametrize("name,malicious,attack", ATTACK_CASES,
                         ids=[c[0] for c in ATTACK_CASES])
def test_batched_matches_sequential_pigeon(tiny_task, tiny_pcfg, name,
                                           malicious, attack):
    data, module = tiny_task
    h_seq = run_pigeon(module, data, tiny_pcfg, malicious=malicious,
                       attack=attack, engine="sequential")
    h_bat = run_pigeon(module, data, tiny_pcfg, malicious=malicious,
                       attack=attack, engine="batched")
    assert_histories_equivalent(h_seq, h_bat)


@pytest.mark.slow
def test_batched_matches_sequential_pigeon_plus(tiny_task, tiny_pcfg):
    data, module = tiny_task
    h_seq = run_pigeon_plus(module, data, tiny_pcfg, malicious={1},
                            attack=Attack(ACTIVATION), engine="sequential")
    h_bat = run_pigeon_plus(module, data, tiny_pcfg, malicious={1},
                            attack=Attack(ACTIVATION), engine="batched")
    assert_histories_equivalent(h_seq, h_bat)


@pytest.mark.slow
def test_batched_matches_sequential_param_tamper(tiny_task, tiny_pcfg):
    """The handoff tamper-check path (host-side in both engines) must see the
    same validation-time activations and fire the same detections."""
    data, module = tiny_task
    h_seq = run_pigeon(module, data, tiny_pcfg, malicious={0, 1, 3},
                       attack=Attack(PARAM_TAMPER), engine="sequential")
    h_bat = run_pigeon(module, data, tiny_pcfg, malicious={0, 1, 3},
                       attack=Attack(PARAM_TAMPER), engine="batched")
    assert_histories_equivalent(h_seq, h_bat)
    assert sum(r["detections"] for r in h_bat.rounds) >= 1


@pytest.mark.slow
def test_batched_matches_sequential_splitfed(tiny_task, tiny_pcfg):
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, lr=0.5)
    h_seq = run_splitfed(module, data, pcfg, malicious={1},
                         attack=Attack(LABEL_FLIP), engine="sequential")
    h_bat = run_splitfed(module, data, pcfg, malicious={1},
                         attack=Attack(LABEL_FLIP), engine="batched")
    for rs, rb in zip(h_seq.rounds, h_bat.rounds):
        assert rs["selected"] == rb["selected"]
        np.testing.assert_allclose(rs["val_losses"], rb["val_losses"],
                                   rtol=2e-5, atol=1e-6)


def test_engine_rejects_unknown_name(tiny_task, tiny_pcfg):
    data, module = tiny_task
    with pytest.raises(ValueError, match="engine"):
        run_pigeon(module, data, tiny_pcfg, malicious=set(), engine="warp")


# ---------------------------------------------------------------------------
# heterogeneous threat models and schedules (the adversary subsystem)
# ---------------------------------------------------------------------------

def test_batched_matches_sequential_mixed_population(tiny_task, tiny_pcfg):
    """A mixed malicious population — one label flipper, one Byzantine
    gradient scaler, one gradient-noise client — must run as one batched
    program and still match the per-client jit-specialised oracle."""
    data, module = tiny_task
    tm = ThreatModel.build({
        0: Attack(LABEL_FLIP),
        1: Attack(GRAD_SCALE, grad_scale=6.0),
        3: Attack(GRAD_NOISE, noise_std=0.5),
    })
    h_seq = run_pigeon(module, data, tiny_pcfg, threat_model=tm,
                       engine="sequential")
    h_bat = run_pigeon(module, data, tiny_pcfg, threat_model=tm,
                       engine="batched")
    assert_histories_equivalent(h_seq, h_bat)


def test_batched_matches_sequential_intermittent_schedule(tiny_task, tiny_pcfg):
    """Round-indexed schedules: an every-2 flipper plus a post-warmup
    activation tamperer change the AttackVec *data* each round; both engines
    must gate the same rounds."""
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, T=3)
    tm = ThreatModel.build({
        1: ClientThreat(Attack(LABEL_FLIP), every_k(2)),
        2: ClientThreat(Attack(ACTIVATION), after_warmup(1)),
    })
    h_seq = run_pigeon(module, data, pcfg, threat_model=tm, engine="sequential")
    h_bat = run_pigeon(module, data, pcfg, threat_model=tm, engine="batched")
    assert_histories_equivalent(h_seq, h_bat)


NEW_FAMILY_CASES = [
    ("backdoor", ThreatModel.build({1: Attack(BACKDOOR, target=7)})),
    ("replay", ThreatModel.build({1: Attack(REPLAY)})),
    ("stealth", ThreatModel.build({1: stealth()})),
    ("grad_noise", ThreatModel.build({1: Attack(GRAD_NOISE, noise_std=2.0)})),
    ("ramp_grad_scale",
     ThreatModel.build({1: ClientThreat(Attack(GRAD_SCALE, grad_scale=5.0),
                                        ramp(3))})),
]


@pytest.mark.slow
@pytest.mark.parametrize("name,tm", NEW_FAMILY_CASES,
                         ids=[c[0] for c in NEW_FAMILY_CASES])
def test_batched_matches_sequential_new_families(tiny_task, tiny_pcfg, name, tm):
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, T=3)
    h_seq = run_pigeon(module, data, pcfg, threat_model=tm, engine="sequential")
    h_bat = run_pigeon(module, data, pcfg, threat_model=tm, engine="batched")
    assert_histories_equivalent(h_seq, h_bat)


@pytest.mark.slow
def test_sweep_matches_per_seed_heterogeneous(tiny_task, tiny_pcfg):
    """The multi-seed sweep accepts a heterogeneous scheduled threat model
    and reproduces each single-seed batched trajectory."""
    data, module = tiny_task
    tm = ThreatModel.build({
        0: ClientThreat(Attack(LABEL_FLIP), every_k(2)),
        1: Attack(GRAD_SCALE, grad_scale=4.0),
    })
    hists = run_pigeon_sweep(module, data, tiny_pcfg, threat_model=tm,
                             seeds=(0, 1))
    for i, seed in enumerate((0, 1)):
        h_ref = run_pigeon(module, data,
                           dataclasses.replace(tiny_pcfg, seed=seed),
                           threat_model=tm, engine="batched")
        for rr, rw in zip(h_ref.rounds, hists[i].rounds):
            assert rr["selected"] == rw["selected"]
            np.testing.assert_allclose(rr["val_losses"], rw["val_losses"],
                                       rtol=2e-5, atol=1e-6)


def test_param_tamper_rollback_reselect_batched(tiny_task, tiny_pcfg):
    """End-to-end III-C path under the batched engine: a detected tampered
    handoff must be recorded in History AND trigger reselection — the
    recorded winner deviates from the raw validation argmin, and the cluster
    that ends up selected has an honest last client (its handoff passed)."""
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, T=3)
    h = run_pigeon(module, data, pcfg, malicious={0, 1, 3},
                   attack=Attack(PARAM_TAMPER), engine="batched")
    assert sum(r["detections"] for r in h.rounds) >= 1
    reselected = [r for r in h.rounds
                  if r["detections"] >= 1
                  and r["selected"] != int(np.argmin(r["val_losses"]))]
    assert reselected, [(r["detections"], r["selected"], r["val_losses"])
                        for r in h.rounds]
    for r in reselected:
        assert r["clusters"][r["selected"]][-1] == 2   # the only honest client


def test_threat_model_and_legacy_args_are_exclusive(tiny_task, tiny_pcfg):
    data, module = tiny_task
    tm = ThreatModel.build({1: Attack(LABEL_FLIP)})
    with pytest.raises(ValueError, match="threat_model"):
        run_pigeon(module, data, tiny_pcfg, malicious={1},
                   attack=Attack(LABEL_FLIP), threat_model=tm)
    with pytest.raises(ValueError, match="threat_model"):
        run_vanilla_sl(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), threat_model=tm)


@pytest.mark.slow
def test_sweep_matches_per_seed_runs(tiny_task, tiny_pcfg):
    """Each replica of the vmapped multi-seed sweep reproduces the
    corresponding single-seed batched run (selection happens on device, so
    only tamper_check-free trajectories are comparable)."""
    data, module = tiny_task
    hists = run_pigeon_sweep(module, data, tiny_pcfg, malicious={1},
                             attack=Attack(LABEL_FLIP), seeds=(0, 1))
    for i, seed in enumerate((0, 1)):
        h_ref = run_pigeon(module, data, dataclasses.replace(tiny_pcfg, seed=seed),
                           malicious={1}, attack=Attack(LABEL_FLIP),
                           engine="batched")
        for rr, rw in zip(h_ref.rounds, hists[i].rounds):
            assert rr["clusters"] == rw["clusters"]
            assert rr["selected"] == rw["selected"]
            np.testing.assert_allclose(rr["val_losses"], rw["val_losses"],
                                       rtol=2e-5, atol=1e-6)
            assert rr["comm"] == rw["comm"]      # analytic meter matches exactly
            if "test_acc" in rr:
                assert abs(rr["test_acc"] - rw["test_acc"]) < 1e-6


def test_sweep_rejects_param_tamper(tiny_task, tiny_pcfg):
    data, module = tiny_task
    with pytest.raises(ValueError, match="param-tamper"):
        run_pigeon_sweep(module, data, tiny_pcfg, malicious={1},
                         attack=Attack(PARAM_TAMPER))


# ---------------------------------------------------------------------------
# unit-level: vectorised attack transforms vs their static counterparts
# ---------------------------------------------------------------------------

def test_attack_vec_transforms_match_static():
    key = jax.random.PRNGKey(7)
    y = jnp.arange(16) % 10
    acts = jax.random.normal(key, (8, 32))
    g = jax.random.normal(jax.random.fold_in(key, 1), (8, 32))

    for kind, static_fn, vec_fn, args in [
        (LABEL_FLIP, flip_labels, flip_labels_vec, (y, 10)),
        (GRADIENT, tamper_gradient, tamper_gradient_vec,
         (g, jax.random.fold_in(key, 3))),
    ]:
        a = Attack(kind)
        av_on = attack_vec(a, True)
        av_off = attack_vec(a, False)
        np.testing.assert_array_equal(static_fn(a, *args), vec_fn(av_on, *args))
        np.testing.assert_array_equal(args[0], vec_fn(av_off, *args))

    a = Attack(ACTIVATION)
    k2 = jax.random.fold_in(key, 2)
    np.testing.assert_array_equal(tamper_activation(a, acts, k2),
                                  tamper_activation_vec(attack_vec(a, True), acts, k2))
    np.testing.assert_array_equal(acts,
                                  tamper_activation_vec(attack_vec(a, False), acts, k2))


def test_client_update_vec_matches_static(tiny_task):
    """One client's E-step chain: the vectorised update must be bit-identical
    to the static-attack jit specialisation, honest and attacked."""
    data, module = tiny_task
    gamma, phi = module.init(jax.random.PRNGKey(0))
    xs = jnp.asarray(data.x[0][:32]).reshape(2, 16, *data.x[0].shape[1:])
    ys = jnp.asarray(data.y[0][:32]).reshape(2, 16)
    key = jax.random.PRNGKey(3)
    for attack, active in [(HONEST, False), (Attack(LABEL_FLIP), True),
                           (Attack(ACTIVATION), True), (Attack(GRADIENT), True)]:
        g_s, p_s, l_s = client_update(module, attack if active else HONEST,
                                      gamma, phi, (xs, ys), 0.05, key)
        g_v, p_v, l_v = client_update_vec(module, attack_vec(attack, active),
                                          gamma, phi, (xs, ys), 0.05, key)
        for a, b in zip(jax.tree.leaves((g_s, p_s)), jax.tree.leaves((g_v, p_v))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(float(l_s), float(l_v), rtol=1e-6)


def test_attack_vec_for_clusters_shapes_and_param_tamper_trains_honestly():
    clusters = [[0, 1], [2, 3]]
    av = attack_vec_for_clusters(Attack(LABEL_FLIP), clusters, {1, 2})
    assert av.flip.shape == (2, 2)
    np.testing.assert_array_equal(np.asarray(av.flip),
                                  [[False, True], [True, False]])
    # Section III-C: param-tampering clients avoid raising validation loss,
    # so their training-phase attack state is fully honest
    av_pt = attack_vec_for_clusters(Attack(PARAM_TAMPER), clusters, {1, 2})
    assert not np.asarray(av_pt.flip).any()
    assert not np.asarray(av_pt.act).any()
    assert not np.asarray(av_pt.grad).any()


def test_onehot_select_picks_leading_index():
    stacked = {"w": jnp.arange(12.0).reshape(4, 3),
               "b": jnp.arange(8.0).reshape(4, 2)}
    out = onehot_select(stacked, jnp.int32(2))
    np.testing.assert_array_equal(np.asarray(out["w"]), [6.0, 7.0, 8.0])
    np.testing.assert_array_equal(np.asarray(out["b"]), [4.0, 5.0])
