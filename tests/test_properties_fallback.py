"""Deterministic fallback cases for the invariants in ``test_properties.py``.

That module needs the optional ``hypothesis`` package and is skipped wholesale
when it is missing; the seeded grids below cover the same properties with
plain pytest so the invariants never go untested.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attacks import (ACTIVATION, GRADIENT, LABEL_FLIP, Attack,
                                flip_labels, tamper_activation, tamper_gradient)
from repro.core.clustering import has_honest_cluster, make_clusters
from repro.launch.hlo_analysis import _shape_dims, _type_bytes
from repro.models.moe import MoEConfig, capacity


# ---------------------------------------------------------------------------
# pigeonhole clustering invariants (eq. (1) + the honest-cluster guarantee)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,size_per", [(1, 1), (1, 5), (2, 3), (3, 1),
                                        (4, 4), (6, 8)])
@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_clusters_partition_and_pigeonhole(r, size_per, seed):
    m = r * size_per
    rng = np.random.default_rng(seed)
    clusters = make_clusters(rng, m, r)
    # disjoint + covering: every client in exactly one cluster
    all_members = sorted(c for cl in clusters for c in cl)
    assert all_members == list(range(m))
    # exactly R clusters, all non-empty (equal size M/R)
    assert len(clusters) == r
    assert all(len(c) == size_per for c in clusters)
    # pigeonhole for every adversary size up to N = r-1
    for n in range(r):
        malicious = set(rng.choice(m, size=n, replace=False).tolist())
        assert has_honest_cluster(clusters, malicious)


def test_clusters_require_divisibility():
    with pytest.raises(ValueError):
        make_clusters(np.random.default_rng(0), 7, 3)


def test_adversary_can_poison_at_most_n_clusters():
    for r in (2, 3, 4, 6):
        rng = np.random.default_rng(0)
        clusters = make_clusters(rng, r * 3, r)
        malicious = set(range(r - 1))          # worst case: N distinct clients
        touched = sum(1 for cl in clusters if any(c in malicious for c in cl))
        assert touched <= r - 1


# ---------------------------------------------------------------------------
# attack transforms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_classes,shift", [(10, 3), (2, 1), (50, 49), (7, 12)])
def test_label_flip_is_shift_and_stays_in_range(n_classes, shift):
    y = jnp.asarray(np.random.default_rng(0).integers(0, n_classes, 32))
    y2 = flip_labels(Attack(LABEL_FLIP, label_shift=shift), y, n_classes)
    assert bool(jnp.all((y2 >= 0) & (y2 < n_classes)))
    assert bool(jnp.all(((y2 - y) % n_classes) == shift % n_classes))


@pytest.mark.parametrize("b,d,seed", [(1, 2, 0), (8, 64, 1), (4, 16, 7)])
def test_activation_tamper_preserves_scale(b, d, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, (b, d)) + 0.1)
    out = tamper_activation(Attack(ACTIVATION), x, jax.random.PRNGKey(seed))
    xi = np.linalg.norm(np.asarray(x), axis=1)
    oi = np.linalg.norm(np.asarray(out), axis=1)
    # norm-matched noise: triangle inequality bounds the output norm
    assert np.all(oi <= xi * (1 + 1e-4) + 1e-3)
    assert float(jnp.abs(out - x).max()) > 0


def test_gradient_tamper_is_involution():
    g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (5, 32)))
    a = Attack(GRADIENT)
    assert bool(jnp.all(tamper_gradient(a, tamper_gradient(a, g)) == g))
    assert bool(jnp.all(tamper_gradient(a, g) == -g))


# ---------------------------------------------------------------------------
# MoE capacity arithmetic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tokens,n_experts,top_k",
                         [(1, 1, 1), (4096, 64, 8), (100, 7, 3), (8, 64, 1)])
def test_moe_capacity_covers_perfect_balance(tokens, n_experts, top_k):
    top_k = min(top_k, n_experts)
    cfg = MoEConfig(d_model=8, d_expert=8, n_experts=n_experts, top_k=top_k,
                    capacity_factor=1.0)
    c = capacity(tokens, cfg)
    assert c * n_experts >= tokens * top_k       # perfectly balanced fits
    assert c % 8 == 0                            # TPU-aligned slots


# ---------------------------------------------------------------------------
# HLO type parsing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,bytes_per", [("f32", 4), ("bf16", 2),
                                             ("s32", 4), ("pred", 1), ("f16", 2)])
@pytest.mark.parametrize("dims", [[], [1], [2, 3], [4, 8, 16, 2]])
def test_hlo_type_bytes(dtype, bytes_per, dims):
    n = int(np.prod(dims)) if dims else 1
    s = f"{dtype}[{','.join(map(str, dims))}]"
    assert _type_bytes(s) == n * bytes_per
    assert _shape_dims(s) == dims


# ---------------------------------------------------------------------------
# checkpoint roundtrip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,depth", [(0, 1), (3, 3)])
def test_checkpoint_roundtrip(seed, depth):
    from repro.checkpoint import restore_pytree, save_checkpoint
    rng = np.random.default_rng(seed)

    def rand_tree(d):
        if d == 0:
            return jnp.asarray(rng.normal(0, 1, rng.integers(1, 5, size=2)))
        return {f"k{i}": rand_tree(d - 1) for i in range(2)}

    tree = rand_tree(depth)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_checkpoint(path, tree, {"seed": seed})
        back = restore_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
