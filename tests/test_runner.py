"""RoundRunner placements + double-buffered host pipeline.

Equivalence contract: the sharded placement (cluster axis laid over a
("pod",) host mesh via shard_map) must reproduce the vmap placement and the
sequential oracle — same selection every round, validation losses within
float tolerance, bit-identical CommMeter counts — and the prefetching
RoundFeeder must leave the trajectory bit-identical to synchronous assembly.

The sharded tests run at any device count (the runner sizes the mesh to the
largest divisor of R that fits); the multi-device assertions only engage
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — CI runs this
file a second time under that flag so the shard_map path cannot rot.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HONEST, LABEL_FLIP, Attack, ProtocolConfig,
                        run_pigeon, run_pigeon_plus, run_pigeon_sweep,
                        run_splitfed)
from repro.core.engine import assemble_round_batches, sample_batch_idx
from repro.core.runner import (PLACEMENTS, RoundRunner, RoundSpec,
                               backend_supports_partial_auto, cluster_map,
                               cluster_mesh, onehot_select, sweep_map,
                               sweep_mesh)
from repro.data.pipeline import RoundFeeder

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the dedicated CI multi-device step sets it)")


def assert_histories_equivalent(h_a, h_b, exact=False):
    assert len(h_a.rounds) == len(h_b.rounds)
    for ra, rb in zip(h_a.rounds, h_b.rounds):
        assert ra["clusters"] == rb["clusters"]
        assert ra["selected"] == rb["selected"], (ra["round"], ra, rb)
        assert ra["comm"] == rb["comm"]          # bit-identical float counts
        if exact:
            assert ra["val_losses"] == rb["val_losses"]
            assert ra.get("test_acc") == rb.get("test_acc")
        else:
            np.testing.assert_allclose(ra["val_losses"], rb["val_losses"],
                                       rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# sharded placement vs vmap placement vs sequential oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("malicious,attack", [(set(), HONEST),
                                              ({1}, Attack(LABEL_FLIP))],
                         ids=["honest", "label_flip"])
def test_sharded_matches_vmap(tiny_task, tiny_pcfg, malicious, attack):
    data, module = tiny_task
    h_v = run_pigeon(module, data, tiny_pcfg, malicious=malicious,
                     attack=attack, engine="batched", placement="vmap")
    h_s = run_pigeon(module, data, tiny_pcfg, malicious=malicious,
                     attack=attack, engine="batched", placement="sharded")
    assert_histories_equivalent(h_v, h_s)


def test_sharded_matches_sequential_oracle(tiny_task, tiny_pcfg):
    data, module = tiny_task
    h_seq = run_pigeon(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="sequential")
    h_s = run_pigeon(module, data, tiny_pcfg, malicious={1},
                     attack=Attack(LABEL_FLIP), engine="batched",
                     placement="sharded")
    assert_histories_equivalent(h_seq, h_s)


def test_placement_validation(tiny_task, tiny_pcfg):
    data, module = tiny_task
    with pytest.raises(ValueError, match="placement"):
        run_pigeon(module, data, tiny_pcfg, engine="batched", placement="warp")
    with pytest.raises(ValueError, match="batched"):
        run_pigeon(module, data, tiny_pcfg, engine="sequential",
                   placement="sharded")
    with pytest.raises(ValueError, match="batched"):
        run_pigeon(module, data, tiny_pcfg, engine="sequential", prefetch=1)
    assert PLACEMENTS == ("vmap", "sharded")


@multi_device
def test_cluster_mesh_uses_multiple_devices():
    """R=4 on the forced 8-device host must land on a real 4-way pod mesh
    (largest divisor of R that fits), not silently collapse to one device."""
    mesh = cluster_mesh(4)
    assert mesh.shape["pod"] == 4
    assert cluster_mesh(3).shape["pod"] in (1, 3)
    assert cluster_mesh(16).shape["pod"] == jax.device_count()


@multi_device
def test_sharded_multi_device_matches_oracle(tiny_task):
    """True multi-device run: R=4 clusters over a 4-device pod mesh, checked
    against the sequential oracle (selection + losses + comm)."""
    data, module = tiny_task
    pcfg = ProtocolConfig(M=4, N=3, T=2, E=2, B=16, lr=0.05, seed=0)
    h_seq = run_pigeon(module, data, pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="sequential")
    h_s = run_pigeon(module, data, pcfg, malicious={1},
                     attack=Attack(LABEL_FLIP), engine="batched",
                     placement="sharded")
    assert_histories_equivalent(h_seq, h_s)


@multi_device
def test_runner_round_selects_and_broadcasts_across_devices():
    """The in-program selection path (round_fn) on a real pod mesh: winner
    broadcast must equalise every cluster slot."""
    spec = RoundSpec(
        train_cluster=lambda p, b: (jax.tree.map(lambda w: w - 0.1 * b.mean(), p),
                                    b.mean()),
        validate=lambda p, val: (jnp.mean((p["w"] - val) ** 2), None))
    runner = RoundRunner(spec, placement="sharded", params_stacked=True)
    r = 4
    stacked = {"w": jnp.arange(float(r * 3)).reshape(r, 3)}
    batches = jnp.ones((r, 2)) * jnp.arange(float(r))[:, None]
    rebro, vlosses, sel = runner.round(stacked, batches, jnp.zeros(3))
    assert vlosses.shape == (r,)
    assert int(sel) == int(np.argmin(np.asarray(vlosses)))
    for i in range(1, r):
        np.testing.assert_allclose(np.asarray(rebro["w"][0]),
                                   np.asarray(rebro["w"][i]))
    # must match the vmap placement bit-for-bit on CPU
    runner_v = RoundRunner(spec, placement="vmap", params_stacked=True)
    rebro_v, vlosses_v, sel_v = runner_v.round(stacked, batches, jnp.zeros(3))
    np.testing.assert_array_equal(np.asarray(vlosses), np.asarray(vlosses_v))
    np.testing.assert_array_equal(np.asarray(rebro["w"]),
                                  np.asarray(rebro_v["w"]))


# ---------------------------------------------------------------------------
# SplitFed placements (FedAvg combine hook) + sweep placements (2-D mesh)
# ---------------------------------------------------------------------------

def assert_selection_histories_equivalent(h_a, h_b, exact=False):
    """SplitFed records carry (selected, val_losses, selected_honest,
    test_acc) but no clusters/comm — compare what both have."""
    assert len(h_a.rounds) == len(h_b.rounds)
    for ra, rb in zip(h_a.rounds, h_b.rounds):
        assert ra["selected"] == rb["selected"], (ra["round"], ra, rb)
        assert ra["selected_honest"] == rb["selected_honest"]
        if exact:
            assert ra["val_losses"] == rb["val_losses"]
            assert ra.get("test_acc") == rb.get("test_acc")
        else:
            np.testing.assert_allclose(ra["val_losses"], rb["val_losses"],
                                       rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("malicious,attack", [(set(), HONEST),
                                              ({1}, Attack(LABEL_FLIP))],
                         ids=["honest", "label_flip"])
def test_splitfed_placements_match_sequential_oracle(tiny_task, tiny_pcfg,
                                                     malicious, attack):
    data, module = tiny_task
    h_seq = run_splitfed(module, data, tiny_pcfg, malicious=malicious,
                         attack=attack, engine="sequential")
    for placement in PLACEMENTS:
        h = run_splitfed(module, data, tiny_pcfg, malicious=malicious,
                         attack=attack, engine="batched", placement=placement)
        assert_selection_histories_equivalent(h_seq, h)


def test_splitfed_prefetch_bit_identical(tiny_task, tiny_pcfg):
    """SplitFed sampling never depends on selection, so the feeder runs at
    full depth and the trajectory must equal prefetch=0 bit-for-bit — under
    both placements."""
    data, module = tiny_task
    h_sync = run_splitfed(module, data, tiny_pcfg, malicious={1},
                          attack=Attack(LABEL_FLIP), engine="batched")
    h_pre = run_splitfed(module, data, tiny_pcfg, malicious={1},
                         attack=Attack(LABEL_FLIP), engine="batched",
                         prefetch=2)
    assert_selection_histories_equivalent(h_sync, h_pre, exact=True)
    h_pre_sharded = run_splitfed(module, data, tiny_pcfg, malicious={1},
                                 attack=Attack(LABEL_FLIP), engine="batched",
                                 placement="sharded", prefetch=1)
    assert_selection_histories_equivalent(h_sync, h_pre_sharded)


def test_splitfed_placement_validation(tiny_task, tiny_pcfg):
    data, module = tiny_task
    with pytest.raises(ValueError, match="placement"):
        run_splitfed(module, data, tiny_pcfg, engine="batched",
                     placement="warp")
    with pytest.raises(ValueError, match="batched"):
        run_splitfed(module, data, tiny_pcfg, engine="sequential",
                     placement="sharded")
    with pytest.raises(ValueError, match="batched"):
        run_splitfed(module, data, tiny_pcfg, engine="sequential", prefetch=1)


def test_combine_hook_applies_before_validation():
    """RoundSpec.combine (SplitFed's FedAvg fan-in) must transform the
    per-client stack into the cluster model the validator sees."""
    spec = RoundSpec(
        train_cluster=lambda p, b: (p + b, b.sum(axis=-1)),   # (M_bar,) out
        validate=lambda p, val: (jnp.abs(p - val), None),
        combine=lambda p: jnp.mean(p, axis=0))
    params = jnp.float32(1.0)
    inputs = jnp.arange(6.0).reshape(2, 3)        # R=2 clusters, M_bar=3
    new_p, aux, vl, _ = cluster_map(spec, params, inputs, jnp.float32(0.0))
    np.testing.assert_allclose(np.asarray(new_p), [2.0, 5.0])   # mean(1 + b)
    np.testing.assert_allclose(np.asarray(vl), [2.0, 5.0])
    for placement in PLACEMENTS:
        c = RoundRunner(spec, placement=placement).candidates(
            params, inputs, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(new_p))


def test_sweep_sharded_matches_vmap(tiny_task, tiny_pcfg):
    """The 2-D (seed, cluster) placement must reproduce the vmap sweep —
    same per-seed selections and losses, every round."""
    data, module = tiny_task
    h_v = run_pigeon_sweep(module, data, tiny_pcfg, malicious={1},
                           attack=Attack(LABEL_FLIP), seeds=(0, 1))
    h_s = run_pigeon_sweep(module, data, tiny_pcfg, malicious={1},
                           attack=Attack(LABEL_FLIP), seeds=(0, 1),
                           placement="sharded")
    assert len(h_v) == len(h_s) == 2
    for h_a, h_b in zip(h_v, h_s):
        assert len(h_a.rounds) == len(h_b.rounds)
        for ra, rb in zip(h_a.rounds, h_b.rounds):
            assert ra["clusters"] == rb["clusters"]
            assert ra["selected"] == rb["selected"]
            assert ra["comm"] == rb["comm"]
            np.testing.assert_allclose(ra["val_losses"], rb["val_losses"],
                                       rtol=2e-5, atol=1e-6)


def test_sweep_map_selects_per_seed():
    """Unit check of the sweep body: per-seed argmin + winner carry."""
    spec = RoundSpec(
        train_cluster=lambda p, b: (p + b.sum(), b.sum()),
        validate=lambda p, val: (jnp.abs(p - val), None))
    params = jnp.array([0.0, 10.0])                     # S=2 seeds
    inputs = jnp.array([[[1.0], [4.0]], [[2.0], [3.0]]])  # (S=2, R=2, 1)
    winners, aux, vlosses, sels = sweep_map(spec, params, inputs,
                                            jnp.float32(5.0))
    # seed 0: candidates 1, 4 -> |1-5|=4 vs |4-5|=1 -> cluster 1 wins (4.0)
    # seed 1: candidates 12, 13 -> 7 vs 8 -> cluster 0 wins (12.0)
    np.testing.assert_array_equal(np.asarray(sels), [1, 0])
    np.testing.assert_allclose(np.asarray(winners), [4.0, 12.0])
    assert vlosses.shape == (2, 2)


@multi_device
def test_sweep_mesh_factorisation():
    """On the forced 8-device host the sweep mesh must cover as many devices
    as (divisor of S) x (divisor of R) allows."""
    assert dict(sweep_mesh(2, 4).shape) == {"seed": 2, "pod": 4}
    assert dict(sweep_mesh(2, 2).shape) == {"seed": 2, "pod": 2}
    assert dict(sweep_mesh(3, 4).shape) == {"seed": 3, "pod": 2}
    assert dict(sweep_mesh(1, 16).shape) == {"seed": 1, "pod": 8}


def test_largest_divisor_properties():
    """_largest_divisor(n, cap): a divisor of n, <= cap, >= 1 — including
    degenerate caps (0, negative) and prime n, where it must degrade to 1
    rather than divide by zero."""
    from repro.core.runner import _largest_divisor
    for n in range(1, 25):
        for cap in range(-2, 25):
            d = _largest_divisor(n, cap)
            assert d >= 1 and n % d == 0
            assert cap < 1 or d <= cap
            # maximality: no larger divisor fits the cap
            assert not any(n % e == 0 for e in range(d + 1,
                                                     max(cap, 1) + 1))


@pytest.mark.parametrize("devices", [1, 2, 3, 5, 7, 8, 12])
def test_sweep_mesh_packing_properties(devices):
    """Property grid over (S, R, device-count), emulated via max_devices:
    the (seed, pod) factorisation always divides (S, R), fits the device
    budget, and never covers fewer devices than the widest 1-D cluster mesh
    — prime/non-factoring S and R (e.g. 7 x 11 on 8 devices) must fall back
    to the 1-D cluster mesh, not collapse to a 1x1 grid."""
    from repro.core.runner import _largest_divisor
    budget = min(devices, jax.device_count())
    for s in (1, 2, 3, 4, 5, 7, 11):
        for r in (1, 2, 3, 4, 6, 7, 11, 13):
            shape = dict(sweep_mesh(s, r, max_devices=devices).shape)
            sn, rn = shape["seed"], shape["pod"]
            assert s % sn == 0 and r % rn == 0
            assert 1 <= sn * rn <= budget
            one_d = dict(cluster_mesh(r, max_devices=devices).shape)["pod"]
            assert one_d == _largest_divisor(r, budget)
            assert sn * rn >= one_d, (s, r, devices)


@multi_device
def test_sweep_sharded_multi_device_matches_vmap(tiny_task):
    """S x R = 2 x 2 replicas over a real (2, 2) device mesh."""
    data, module = tiny_task
    pcfg = ProtocolConfig(M=4, N=1, T=2, E=2, B=16, lr=0.05, seed=0)
    h_v = run_pigeon_sweep(module, data, pcfg, malicious={1},
                           attack=Attack(LABEL_FLIP), seeds=(0, 1))
    h_s = run_pigeon_sweep(module, data, pcfg, malicious={1},
                           attack=Attack(LABEL_FLIP), seeds=(0, 1),
                           placement="sharded")
    for h_a, h_b in zip(h_v, h_s):
        for ra, rb in zip(h_a.rounds, h_b.rounds):
            assert ra["selected"] == rb["selected"]
            np.testing.assert_allclose(ra["val_losses"], rb["val_losses"],
                                       rtol=2e-5, atol=1e-6)


@multi_device
def test_splitfed_sharded_multi_device_matches_oracle(tiny_task):
    """R=4 SplitFed clusters over a 4-device pod mesh vs the sequential
    oracle."""
    data, module = tiny_task
    pcfg = ProtocolConfig(M=4, N=3, T=2, E=2, B=16, lr=0.05, seed=0)
    h_seq = run_splitfed(module, data, pcfg, malicious={1},
                         attack=Attack(LABEL_FLIP), engine="sequential")
    h_s = run_splitfed(module, data, pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="batched",
                       placement="sharded")
    assert_selection_histories_equivalent(h_seq, h_s)


# ---------------------------------------------------------------------------
# CPU backend gate for partial-auto meshes (ROADMAP open item)
# ---------------------------------------------------------------------------

@multi_device
def test_partial_auto_cpu_gate_raises_clear_error():
    """A mesh with GSPMD-auto axes of size > 1 on CPU cannot execute (XLA has
    no PartitionId under SPMD there) — the runner must refuse with a clear
    error at the execution entry instead of letting XLA crash.  The same
    mesh stays usable for dry-run lowering (gate-free ``*_fn`` bodies)."""
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("pod", "data"))
    assert not backend_supports_partial_auto(mesh, ("pod",))
    spec = RoundSpec(train_cluster=lambda p, b: (p, b),
                     validate=lambda p, v: (jnp.float32(0), None))
    runner = RoundRunner(spec, placement="sharded", mesh=mesh)
    with pytest.raises(RuntimeError, match="partial-auto.*CPU"):
        runner.round(jnp.zeros(()), jnp.zeros((4, 2)), jnp.zeros(()))
    with pytest.raises(RuntimeError, match="partial-auto.*CPU"):
        runner.candidates(jnp.zeros(()), jnp.zeros((4, 2)), jnp.zeros(()))
    # fully-manual meshes (no auto axes) stay allowed on CPU
    manual = Mesh(np.array(jax.devices()[:2]), ("pod",))
    assert backend_supports_partial_auto(manual, ("pod",))
    # lowering the same partial-auto program is still supported
    jax.jit(runner.round_fn()).lower(
        jnp.zeros(()), jnp.zeros((4, 2)), jnp.zeros(()))


def test_sharded_rejects_indivisible_mesh(tiny_task):
    """An explicit mesh whose pod axis does not divide R must be refused,
    not silently mis-sharded."""
    from jax.sharding import Mesh
    spec = RoundSpec(train_cluster=lambda p, b: (p, b),
                     validate=lambda p, v: (jnp.float32(0), None))
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    runner = RoundRunner(spec, placement="sharded", mesh=mesh)
    if jax.device_count() < 2:
        pytest.skip("cannot build an indivisible mesh on one device")
    mesh2 = Mesh(np.array(jax.devices()[:2]), ("pod",))
    runner2 = RoundRunner(spec, placement="sharded", mesh=mesh2)
    with pytest.raises(ValueError, match="divisible"):
        runner2.round(jnp.zeros(()), jnp.zeros((3, 2)), jnp.zeros(()))


# ---------------------------------------------------------------------------
# double-buffered host pipeline
# ---------------------------------------------------------------------------

def test_prefetch_history_bit_identical(tiny_task, tiny_pcfg):
    """The feeder consumes the numpy RNG and JAX key stream in exactly the
    synchronous order, so prefetch on/off trajectories are bit-identical —
    same floats, not merely within tolerance."""
    data, module = tiny_task
    h_sync = run_pigeon(module, data, tiny_pcfg, malicious={1},
                        attack=Attack(LABEL_FLIP), engine="batched")
    h_pre = run_pigeon(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="batched", prefetch=1)
    assert_histories_equivalent(h_sync, h_pre, exact=True)
    h_pre2 = run_pigeon(module, data, tiny_pcfg, malicious={1},
                        attack=Attack(LABEL_FLIP), engine="batched",
                        prefetch=2, placement="sharded")
    assert_histories_equivalent(h_sync, h_pre2, exact=True)


def test_prefetch_plus_phase_boundary_fallback(tiny_task, tiny_pcfg):
    """Pigeon-SL+ sub-rounds sample the *selected* cluster, so the feeder
    must bound its depth to zero — prefetch is accepted but the trajectory
    equals the synchronous one."""
    data, module = tiny_task
    h_sync = run_pigeon_plus(module, data, tiny_pcfg, malicious={1},
                             attack=Attack(LABEL_FLIP), engine="batched")
    h_pre = run_pigeon_plus(module, data, tiny_pcfg, malicious={1},
                            attack=Attack(LABEL_FLIP), engine="batched",
                            prefetch=2)
    assert_histories_equivalent(h_sync, h_pre, exact=True)


def test_round_feeder_orders_and_bounds():
    produced = []

    def make_round(t):
        produced.append(t)
        return t * 10

    feeder = RoundFeeder(make_round, 0, 6, depth=1)
    try:
        for t in range(6):
            assert feeder.get(t) == t * 10
    finally:
        feeder.close()
    assert produced == list(range(6))       # strictly ascending — RNG order


def test_round_feeder_rejects_out_of_order_and_propagates_errors():
    def boom(t):
        if t == 1:
            raise RuntimeError("assembly failed")
        return t

    feeder = RoundFeeder(boom, 0, 3, depth=2)
    try:
        assert feeder.get(0) == 0
        with pytest.raises(RuntimeError, match="assembly failed"):
            feeder.get(1)
    finally:
        feeder.close()

    feeder = RoundFeeder(lambda t: t, 0, 3, depth=1)
    try:
        with pytest.raises(RuntimeError, match="out of order"):
            feeder.get(2)
    finally:
        feeder.close()


def test_round_feeder_close_unblocks_producer():
    started = threading.Event()

    def make_round(t):
        started.set()
        return t

    feeder = RoundFeeder(make_round, 0, 1000, depth=1)
    started.wait(timeout=5)
    feeder.close()                           # producer blocked on a full queue
    feeder.close()                           # idempotent
    assert feeder._thread is None


def test_round_feeder_depth_zero_is_synchronous():
    calls = []
    feeder = RoundFeeder(lambda t: calls.append(t) or t, 0, 4, depth=0)
    assert feeder.get(0) == 0
    assert calls == [0]                      # nothing assembled ahead
    assert feeder.get(1) == 1
    feeder.close()


# ---------------------------------------------------------------------------
# single-copy round assembly
# ---------------------------------------------------------------------------

def test_assemble_round_batches_matches_reference(tiny_task, tiny_pcfg):
    """The preallocated np.take path must consume the RNG identically to the
    historical stack-of-stacks implementation and produce the same arrays."""
    data, _ = tiny_task
    clusters = [[0, 1], [2, 3]]
    xs, ys = assemble_round_batches(np.random.default_rng(7), data, clusters,
                                    tiny_pcfg)

    rng = np.random.default_rng(7)
    xs_ref, ys_ref = [], []
    for cluster in clusters:
        xs_c, ys_c = [], []
        for client in cluster:
            idx = sample_batch_idx(rng, data.x[client].shape[0],
                                   tiny_pcfg.E, tiny_pcfg.B)
            xs_c.append(data.x[client][idx])
            ys_c.append(data.y[client][idx])
        xs_ref.append(np.stack(xs_c))
        ys_ref.append(np.stack(ys_c))
    np.testing.assert_array_equal(np.asarray(xs), np.stack(xs_ref))
    np.testing.assert_array_equal(np.asarray(ys), np.stack(ys_ref))
    assert xs.shape == (2, 2, tiny_pcfg.E, tiny_pcfg.B) + data.x.shape[2:]


# ---------------------------------------------------------------------------
# one source of truth: the launch adapter runs the same round body
# ---------------------------------------------------------------------------

def test_cluster_map_is_shared_by_both_layers():
    """A toy RoundSpec run through cluster_map, the vmap runner and the
    sharded runner must agree bit-for-bit — there is only one round body."""
    spec = RoundSpec(
        train_cluster=lambda p, b: (p + b.sum(), b.sum()),
        validate=lambda p, val: (jnp.abs(p - val), p * 2))
    params = jnp.float32(1.0)
    inputs = jnp.arange(6.0).reshape(3, 2)
    val = jnp.float32(5.0)
    new_p, aux, vl, vaux = cluster_map(spec, params, inputs, val)
    for placement in PLACEMENTS:
        runner = RoundRunner(spec, placement=placement)
        c = runner.candidates(params, inputs, val)
        np.testing.assert_array_equal(np.asarray(c[0]), np.asarray(new_p))
        np.testing.assert_array_equal(np.asarray(c[2]), np.asarray(vl))
        np.testing.assert_array_equal(np.asarray(c[3]), np.asarray(vaux))


def test_onehot_select_ignores_inf_in_unselected_slots():
    stacked = {"w": jnp.array([[1.0, 2.0], [jnp.inf, jnp.nan]])}
    out = onehot_select(stacked, jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(out["w"]), [1.0, 2.0])


# ---------------------------------------------------------------------------
# round-block execution: K scanned rounds per host sync
# ---------------------------------------------------------------------------

def _block_pcfg(tiny_pcfg, **kw):
    """tiny_pcfg widened to 4 rounds with eval pushed past T so a block can
    actually span multiple rounds (eval rounds are host sync points)."""
    kw.setdefault("T", 4)
    kw.setdefault("eval_every", 10)
    return dataclasses.replace(tiny_pcfg, **kw)


def assert_rounds_identical(h_a, h_b):
    """Full-record bit-identity: every History key, including CommMeter
    totals, detections and train losses — stricter than
    assert_histories_equivalent(exact=True)."""
    assert len(h_a.rounds) == len(h_b.rounds)
    for ra, rb in zip(h_a.rounds, h_b.rounds):
        assert ra.keys() == rb.keys(), (set(ra) ^ set(rb))
        for k in ra:
            assert ra[k] == rb[k], (ra.get("round"), k, ra[k], rb[k])


@pytest.mark.parametrize("malicious,attack,tamper_check", [
    (set(), HONEST, False),
    ({1}, Attack(LABEL_FLIP), False),
    ({1}, Attack(LABEL_FLIP), True),
], ids=["honest", "label_flip", "label_flip+tamper_check"])
def test_block_history_bit_identical(tiny_task, tiny_pcfg, malicious, attack,
                                     tamper_check):
    """block=K must reproduce the per-round trajectory bit-for-bit: same
    selected-cluster sequence, same History floats, same CommMeter totals —
    the K-round scan changes only when the host observes theta, not what is
    computed."""
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg, tamper_check=tamper_check)
    kw = dict(malicious=malicious, attack=attack, engine="batched",
              placement="vmap")
    h_1 = run_pigeon(module, data, pcfg, **kw, block=1)
    h_4 = run_pigeon(module, data, pcfg, **kw, block=4)
    assert_rounds_identical(h_1, h_4)


def test_block_sharded_bit_identical(tiny_task, tiny_pcfg):
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              placement="sharded")
    assert_rounds_identical(run_pigeon(module, data, pcfg, **kw, block=1),
                            run_pigeon(module, data, pcfg, **kw, block=4))


def test_block_selection_policy_bit_identical(tiny_task, tiny_pcfg):
    """Non-default selection policies ride inside the scanned cascade."""
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              placement="vmap", selection="loss_plus_distance")
    assert_rounds_identical(run_pigeon(module, data, pcfg, **kw, block=1),
                            run_pigeon(module, data, pcfg, **kw, block=4))


def test_block_eval_rounds_are_sync_points(tiny_task, tiny_pcfg):
    """Mid-stream eval rounds truncate blocks (plan_blocks) so test_acc is
    computed from exactly the per-round thetas."""
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg, eval_every=2)
    kw = dict(engine="batched", placement="vmap")
    h_1 = run_pigeon(module, data, pcfg, **kw, block=1)
    h_4 = run_pigeon(module, data, pcfg, **kw, block=4)
    assert any("test_acc" in r for r in h_4.rounds[:-1])   # mid-stream eval
    assert_rounds_identical(h_1, h_4)


def test_block_splitfed_bit_identical(tiny_task, tiny_pcfg):
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              placement="vmap")
    assert_rounds_identical(run_splitfed(module, data, pcfg, **kw, block=1),
                            run_splitfed(module, data, pcfg, **kw, block=4))


def test_block_sweep_bit_identical(tiny_task, tiny_pcfg):
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    kw = dict(seeds=[0, 1], malicious={1}, attack=Attack(LABEL_FLIP),
              placement="vmap")
    hs_1 = run_pigeon_sweep(module, data, pcfg, **kw, block=1)
    hs_4 = run_pigeon_sweep(module, data, pcfg, **kw, block=4)
    for h_1, h_4 in zip(hs_1, hs_4):
        assert_rounds_identical(h_1, h_4)


def test_block_prefetch_compose(tiny_task, tiny_pcfg):
    """The feeder assembles whole blocks ahead; prefetch + block together
    still reproduce the synchronous per-round trajectory."""
    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              placement="vmap")
    assert_rounds_identical(
        run_pigeon(module, data, pcfg, **kw, block=1),
        run_pigeon(module, data, pcfg, **kw, block=2, prefetch=2))


def test_check_block_validation(tiny_task, tiny_pcfg):
    """Up-front block validation mirrors _check_engine: impossible combos
    raise before any device work; host-sequenced modes force block=1 with a
    warning rather than silently diverging."""
    from repro.core.protocol import check_block
    data, module = tiny_task
    with pytest.raises(ValueError, match="block=0"):
        check_block(0)
    with pytest.raises(ValueError, match="engine"):
        check_block(2, "sequential")
    with pytest.raises(ValueError, match="checkpoint_every"):
        check_block(2, checkpoint_every=0)
    with pytest.raises(ValueError, match="block"):
        run_pigeon(module, data, tiny_pcfg, engine="sequential", block=2)
    for forced in (dict(plus=True), dict(has_param_tamper=True),
                   dict(force_host_selection=True)):
        with pytest.warns(UserWarning):
            assert check_block(4, **forced) == 1
    with pytest.warns(UserWarning):               # every round is a sync round
        assert check_block(4, eval_every=1) == 4  # kept: plan_blocks degrades
    assert check_block(1, plus=True) == 1         # block=1 never warns


def test_plan_blocks_tiles_and_respects_sync():
    from repro.data.pipeline import plan_blocks
    segs = plan_blocks(0, 10, 4, lambda t: t % 5 == 0 or t == 9)
    assert segs == [(0, 1), (1, 4), (5, 1), (6, 4)]
    assert sum(k for _, k in segs) == 10
    assert plan_blocks(3, 3, 4) == []
    assert plan_blocks(0, 5, 1) == [(t, 1) for t in range(5)]
    with pytest.raises(ValueError):
        plan_blocks(0, 5, 0)


def test_block_donation_no_retrace_and_donated_carry(tiny_task, tiny_pcfg):
    """Steady state of the block path: the second block re-uses the compiled
    scan program (one cached signature — no retrace) and the theta carry
    buffers of the previous block are donated (deleted after the call)."""
    import repro.core.engine as engine
    from repro.adversary import resolve_threat_model
    from repro.core.runner import protocol_accept_runner
    from repro.selection import resolve_policy

    data, module = tiny_task
    pcfg = _block_pcfg(tiny_pcfg)
    tm = resolve_threat_model(set(), HONEST, None)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    theta = module.init(jax.random.PRNGKey(1))
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)
    policy = resolve_policy("argmin")

    runner = protocol_accept_runner(module, pcfg.lr, "vmap", policy,
                                    pcfg.tamper_check, pcfg.tamper_tol,
                                    quant=pcfg.comm.quant)
    key, clusters_k, binputs = engine.assemble_block(rng, key, data, pcfg,
                                                     tm, 0, 2)
    theta1, _ = engine.pigeon_block_accept(module, theta, clusters_k, pcfg,
                                           tm, 0, binputs, x0, y0, policy)
    # the runner (and its compiled programs) is lru-shared across the suite,
    # so assert the steady-state property: a same-shape block adds NO new
    # compiled signature
    sigs = runner._jitted["accept_block"]._cache_size()
    key, clusters_k, binputs = engine.assemble_block(rng, key, data, pcfg,
                                                     tm, 2, 2)
    theta2, fetch = runner.accept_block(theta1, binputs, (x0, y0))
    jax.block_until_ready(fetch)
    assert runner._jitted["accept_block"]._cache_size() == sigs  # no retrace
    assert all(l.is_deleted() for l in jax.tree.leaves(theta1))  # donated


def test_accept_donation_no_retrace_and_donated_carry(tiny_task, tiny_pcfg):
    """Same steady-state guarantees for the existing per-round accept
    program: theta is donated round over round without retracing."""
    import repro.core.engine as engine
    from repro.adversary import resolve_threat_model
    from repro.core.protocol import CommMeter
    from repro.core.protocol import cut_width as protocol_cut_width
    from repro.core.runner import protocol_accept_runner
    from repro.selection import resolve_policy

    data, module = tiny_task
    tm = resolve_threat_model(set(), HONEST, None)
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    theta = module.init(jax.random.PRNGKey(1))
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)
    policy = resolve_policy("argmin")
    meter = CommMeter()
    d_c = protocol_cut_width(module, theta[0], data.x0)

    runner = protocol_accept_runner(module, tiny_pcfg.lr, "vmap", policy,
                                    tiny_pcfg.tamper_check,
                                    tiny_pcfg.tamper_tol,
                                    quant=tiny_pcfg.comm.quant)
    thetas = [theta]
    for t in range(2):
        from repro.core.clustering import make_clusters
        clusters = make_clusters(rng, tiny_pcfg.M, tiny_pcfg.R)
        key, theta_next, _ = engine.pigeon_round_accept(
            module, thetas[-1], clusters, data, tiny_pcfg, tm, t, rng, key,
            meter, d_c, x0, y0, policy)
        thetas.append(theta_next)
        if t == 0:
            sigs = runner._jitted["accept"]._cache_size()
    jax.block_until_ready(thetas[-1])
    assert runner._jitted["accept"]._cache_size() == sigs      # no retrace
    # every superseded carry was donated back to the device allocator
    assert all(l.is_deleted() for l in jax.tree.leaves(thetas[1]))
