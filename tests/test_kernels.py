"""Per-kernel shape/dtype sweeps, asserting allclose against the ref.py
pure-jnp oracles (kernels execute in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mha_via_ref(q, k, v, window):
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    out = ref.mha_reference(qf, kf, vf, window=window)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,s,h,hkv,d,win", [
    (2, 128, 4, 2, 64, 0),
    pytest.param(1, 256, 2, 2, 32, 0, marks=pytest.mark.slow),
    pytest.param(2, 128, 8, 1, 64, 0, marks=pytest.mark.slow),     # MQA
    pytest.param(1, 256, 4, 4, 64, 64, marks=pytest.mark.slow),    # sliding window
    pytest.param(1, 128, 4, 2, 128, 16, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16,
                                                marks=pytest.mark.slow)])
def test_flash_attention_matches_reference(b, s, h, hkv, d, win, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d)).astype(dtype)
    out = ops.flash_attention(q, k, v, window=win, block_q=64, block_k=64,
                              interpret=True)
    expect = _mha_via_ref(q, k, v, win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [
    (64, 64),
    pytest.param(32, 64, marks=pytest.mark.slow),
    pytest.param(128, 32, marks=pytest.mark.slow),
])
def test_flash_attention_block_shapes(block_q, block_k):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))
    out = ops.flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                              interpret=True)
    expect = _mha_via_ref(q, k, v, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


@pytest.mark.parametrize("t,d,v,bt,bv", [
    (256, 64, 512, 64, 128),
    pytest.param(128, 128, 1000, 128, 250, marks=pytest.mark.slow),
    pytest.param(512, 32, 64, 256, 64, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16,
                                                marks=pytest.mark.slow)])
def test_fused_xent_matches_reference(t, d, v, bt, bv, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    h = jax.random.normal(ks[0], (t, d)).astype(dtype)
    w = (jax.random.normal(ks[1], (d, v)) * 0.05).astype(dtype)
    labels = jax.random.randint(ks[2], (t,), 0, v)
    got = ops.fused_cross_entropy(h, w, labels, block_t=bt, block_v=bv,
                                  interpret=True)
    expect = ref.xent_reference(h, w, labels).mean()
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(float(got), float(expect), atol=tol, rtol=tol)


def test_fused_xent_label_edge_cases():
    # labels at vocab block boundaries must hit exactly one panel
    t, d, v = 64, 32, 256
    h = jax.random.normal(jax.random.PRNGKey(3), (t, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (d, v)) * 0.1
    labels = jnp.concatenate([jnp.zeros(16, jnp.int32),
                              jnp.full((16,), 63, jnp.int32),
                              jnp.full((16,), 64, jnp.int32),
                              jnp.full((16,), 255, jnp.int32)])
    got = ops.fused_cross_entropy(h, w, labels, block_t=32, block_v=64,
                                  interpret=True)
    expect = ref.xent_reference(h, w, labels).mean()
    np.testing.assert_allclose(float(got), float(expect), rtol=1e-5)


@pytest.mark.parametrize("n,d", [
    (256, 32),
    pytest.param(512, 128, marks=pytest.mark.slow),
    pytest.param(64, 64, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32,
                                   pytest.param(jnp.bfloat16,
                                                marks=pytest.mark.slow)])
def test_tamper_distance_matches_reference(n, d, dtype):
    a = jax.random.normal(jax.random.PRNGKey(5), (n, d)).astype(dtype)
    b = a + 0.05 * jax.random.normal(jax.random.PRNGKey(6), (n, d)).astype(dtype)
    got = ops.tamper_distance(a, b, block_n=64, interpret=True)
    s = ref.tamper_sums_reference(a, b)
    expect = jnp.sqrt(s[0]) / jnp.sqrt(s[1])
    np.testing.assert_allclose(float(got), float(expect), rtol=2e-2)


def test_tamper_distance_identical_is_zero():
    a = jax.random.normal(jax.random.PRNGKey(7), (128, 64))
    assert float(ops.tamper_distance(a, a, interpret=True)) == 0.0


# ---------------------------------------------------------------------------
# flash-decoding kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hkv,d,win,idx", [
    (2, 256, 4, 2, 64, 0, 255),
    pytest.param(1, 512, 4, 1, 64, 0, 100,
                 marks=pytest.mark.slow),      # partially-filled cache
    pytest.param(2, 256, 2, 2, 32, 64, 200,
                 marks=pytest.mark.slow),      # sliding window
    pytest.param(1, 1024, 8, 2, 128, 0, 1023, marks=pytest.mark.slow),
])
def test_decode_attention_matches_reference(b, s, h, hkv, d, win, idx):
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    got = ops.decode_attention(q, k, v, idx, window=win, block_k=128,
                               interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    expect = ref.decode_attention_reference(qf, kf, vf, idx, window=win)
    expect = expect.reshape(b, h, 1, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-5)


def test_decode_attention_matches_model_gqa_decode():
    """The kernel must agree with the model's XLA decode-attention path."""
    from repro.models import attention as attn
    cfg = attn.AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    b, s, idx = 2, 64, 33
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, 1, 4, 16))
    k = jax.random.normal(ks[1], (b, s, 2, 16))
    v = jax.random.normal(ks[2], (b, s, 2, 16))
    got = ops.decode_attention(q, k, v, idx, block_k=32, interpret=True)
    valid = jnp.arange(s) <= idx
    groups = 4 // 2
    k_all = attn._repeat_kv(k, groups)
    v_all = attn._repeat_kv(v, groups)
    expect = attn.attend(q, k_all, v_all, valid[None, :], 1.0 / 4.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-5)


# ---------------------------------------------------------------------------
# fused sLSTM scan kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,b,d,h", [(16, 2, 32, 2), (32, 1, 64, 4),
                                     (8, 4, 16, 1)])
@pytest.mark.slow
def test_slstm_kernel_matches_reference(t, b, d, h):
    ks = jax.random.split(jax.random.PRNGKey(10), 2)
    pre = jax.random.normal(ks[0], (t, b, 4 * d)) * 0.5
    dh = d // h
    r = jax.random.normal(ks[1], (h, dh, 4 * dh)) / np.sqrt(dh)
    got = ops.slstm_scan(pre, r, n_heads=h, interpret=True)
    expect = ref.slstm_scan_reference(pre, r, n_heads=h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=2e-4)


def test_slstm_kernel_matches_model_layer():
    """Kernel vs the model's slstm_forward inner recurrence (same gating)."""
    from repro.models import xlstm as xl
    cfg = xl.XLSTMConfig(d_model=32, n_heads=2)
    p = xl.slstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32)) * 0.5
    from repro.models.blocks import linear
    pre = linear(p["w_in"], x).swapaxes(0, 1)            # (T, B, 4d)
    hs = ops.slstm_scan(pre, p["r"], n_heads=2, interpret=True)
    # model's forward applies out_norm+down afterwards; compare raw h by
    # reproducing the reference directly
    expect = ref.slstm_scan_reference(pre, p["r"], n_heads=2)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(expect), atol=2e-4)
