"""Unit tests for the pluggable adversary subsystem: registry contents,
static-vs-vectorised transform agreement for the extended families, schedule
arithmetic, strength scaling, and the ThreatModel API (including the legacy
``(malicious, attack)`` bridge)."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adversary import (ACTIVATION, ALWAYS, BACKDOOR, GRAD_NOISE,
                             GRAD_SCALE, GRADIENT, HONEST, KINDS, LABEL_FLIP,
                             NONE, PARAM_TAMPER, REPLAY, STEALTH, Attack,
                             AttackFamily, ClientThreat, Schedule,
                             ThreatModel, after_warmup, attack_vec,
                             attack_vec_grid, every_k, families, flip_labels,
                             flip_labels_vec, get, poison_inputs,
                             poison_inputs_vec, ramp, register,
                             resolve_threat_model, scale_attack, stealth,
                             tamper_activation, tamper_activation_vec,
                             tamper_gradient, tamper_gradient_vec)
from repro.core.attacks import attack_vec_for_clusters


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_every_spec_kind_has_a_registered_family():
    assert set(KINDS) <= set(families())


def test_unknown_family_raises_with_catalogue():
    with pytest.raises(KeyError, match="registered"):
        get("bit_rot")


def test_duplicate_registration_rejected():
    with pytest.raises(AssertionError, match="duplicate"):
        register(AttackFamily(name=LABEL_FLIP, code=1))


def test_stealth_compiles_onto_activation_kernel():
    assert get(STEALTH).code == get(ACTIVATION).code
    assert get(GRADIENT).code == get(GRAD_SCALE).code


# ---------------------------------------------------------------------------
# static vs vectorised transforms, new families
# ---------------------------------------------------------------------------

def test_backdoor_static_matches_vec_and_semantics():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (6, 4, 4, 1))
    y = jnp.arange(6) % 10
    a = Attack(BACKDOOR, target=7, trigger_frac=0.25, trigger_value=3.0)
    av = attack_vec(a, True)

    xs = poison_inputs(a, x)
    np.testing.assert_array_equal(xs, poison_inputs_vec(av, x))
    flat = np.asarray(xs).reshape(6, -1)
    assert np.all(flat[:, :4] == 3.0)                 # round(0.25 * 16) stamped
    np.testing.assert_array_equal(flat[:, 4:], np.asarray(x).reshape(6, -1)[:, 4:])

    ys = flip_labels(a, y, 10)
    np.testing.assert_array_equal(ys, flip_labels_vec(av, y, 10))
    assert np.all(np.asarray(ys) == 7)

    av_off = attack_vec(a, False)
    np.testing.assert_array_equal(x, poison_inputs_vec(av_off, x))
    np.testing.assert_array_equal(y, flip_labels_vec(av_off, y, 10))


def test_replay_static_matches_vec_and_replays_first_sample():
    acts = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    a = Attack(REPLAY)
    k = jax.random.PRNGKey(2)
    out = tamper_activation(a, acts, k)
    np.testing.assert_array_equal(out, tamper_activation_vec(attack_vec(a, True), acts, k))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.tile(np.asarray(acts)[:1], (5, 1)))


def test_grad_scale_and_noise_static_match_vec():
    g = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    k = jax.random.PRNGKey(4)
    a_scale = Attack(GRAD_SCALE, grad_scale=8.0)
    np.testing.assert_array_equal(tamper_gradient(a_scale, g, k),
                                  tamper_gradient_vec(attack_vec(a_scale, True), g, k))
    np.testing.assert_allclose(np.asarray(tamper_gradient(a_scale, g, k)),
                               8.0 * np.asarray(g), rtol=1e-6)

    a_noise = Attack(GRAD_NOISE, noise_std=0.5)
    out = tamper_gradient(a_noise, g, k)
    np.testing.assert_array_equal(out,
                                  tamper_gradient_vec(attack_vec(a_noise, True), g, k))
    assert float(jnp.abs(out - g).max()) > 0
    # honest slots pass the gradient through untouched
    np.testing.assert_array_equal(g, tamper_gradient_vec(attack_vec(a_noise, False), g, k))


def test_tamper_gradient_vec_keyless_legacy_signature():
    """The pre-subsystem 2-arg call must keep working for key-free attack
    state (stochastic gradient kernels are skipped when no key is given)."""
    g = jax.random.normal(jax.random.PRNGKey(7), (4, 8))
    av = attack_vec(Attack(LABEL_FLIP), True)
    np.testing.assert_array_equal(g, tamper_gradient_vec(av, g))
    av_scale = attack_vec(Attack(GRAD_SCALE, grad_scale=3.0), True)
    np.testing.assert_allclose(np.asarray(tamper_gradient_vec(av_scale, g)),
                               3.0 * np.asarray(g), rtol=1e-6)


def test_stealth_is_a_gentle_activation_blend():
    acts = jax.random.normal(jax.random.PRNGKey(5), (8, 32))
    k = jax.random.PRNGKey(6)
    gentle = tamper_activation(stealth(0.97), acts, k)
    loud = tamper_activation(Attack(ACTIVATION), acts, k)
    d_gentle = float(jnp.linalg.norm(gentle - acts))
    d_loud = float(jnp.linalg.norm(loud - acts))
    assert 0 < d_gentle < 0.2 * d_loud
    np.testing.assert_array_equal(
        gentle, tamper_activation_vec(attack_vec(stealth(0.97), True), acts, k))


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def test_schedule_strengths():
    assert [ALWAYS.strength(t) for t in range(3)] == [1.0, 1.0, 1.0]
    assert [every_k(3, offset=1).strength(t) for t in range(8)] == \
        [0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 1.0]
    assert [after_warmup(2).strength(t) for t in range(5)] == \
        [0.0, 0.0, 1.0, 1.0, 1.0]
    assert [after_warmup(1, stop=3).strength(t) for t in range(5)] == \
        [0.0, 1.0, 1.0, 0.0, 0.0]
    assert [ramp(4, start=1).strength(t) for t in range(7)] == \
        [0.0, 0.25, 0.5, 0.75, 1.0, 1.0, 1.0]
    assert every_k(2).active(0) and not every_k(2).active(1)


def test_schedule_rejects_unknown_kind_and_bad_params():
    with pytest.raises(AssertionError):
        Schedule("fortnightly")
    with pytest.raises(AssertionError):
        Schedule("every_k", k=0)


# ---------------------------------------------------------------------------
# strength scaling
# ---------------------------------------------------------------------------

def test_scale_attack_endpoints_and_interpolation():
    a = Attack(ACTIVATION, act_keep=0.2)
    assert scale_attack(a, 1.0) is a           # no spurious jit cache entries
    assert scale_attack(a, 0.0) == HONEST
    assert scale_attack(a, 0.5).act_keep == pytest.approx(0.6)

    g = Attack(GRAD_SCALE, grad_scale=-1.0)
    assert scale_attack(g, 0.5).grad_scale == pytest.approx(0.0)
    assert scale_attack(Attack(GRAD_NOISE, noise_std=2.0), 0.25).noise_std == \
        pytest.approx(0.5)
    assert scale_attack(Attack(PARAM_TAMPER, param_scale=4.0), 0.5).param_scale == \
        pytest.approx(2.0)
    # discrete families gate rather than interpolate
    assert scale_attack(Attack(LABEL_FLIP), 0.5) == Attack(LABEL_FLIP)


# ---------------------------------------------------------------------------
# ThreatModel
# ---------------------------------------------------------------------------

def test_from_legacy_matches_legacy_attack_vec_for_clusters():
    clusters = [[0, 1], [2, 3]]
    a = Attack(LABEL_FLIP, label_shift=4)
    tm = ThreatModel.from_legacy({1, 2}, a)
    av_new = tm.attack_vec_for_clusters(clusters, 0)
    av_old = attack_vec_for_clusters(a, clusters, {1, 2})
    for lane_new, lane_old in zip(av_new, av_old):
        np.testing.assert_array_equal(np.asarray(lane_new), np.asarray(lane_old))
    np.testing.assert_array_equal(np.asarray(av_new.flip),
                                  [[False, True], [True, False]])


def test_attack_for_respects_schedule_and_param_tamper():
    tm = ThreatModel.build({
        0: ClientThreat(Attack(LABEL_FLIP), every_k(2)),
        1: Attack(PARAM_TAMPER),
    })
    assert tm.attack_for(0, 0).kind == LABEL_FLIP
    assert tm.attack_for(0, 1) == HONEST               # off-phase round
    assert tm.attack_for(1, 0) == HONEST               # trains honestly (III-C)
    assert tm.param_attack_for(1, 0).kind == PARAM_TAMPER
    assert tm.param_attack_for(0, 0) is None
    assert tm.malicious == {0, 1}
    assert tm.has_param_tamper


def test_param_tamper_schedule_gates_the_handoff():
    tm = ThreatModel.build({3: ClientThreat(Attack(PARAM_TAMPER),
                                            after_warmup(2))})
    assert tm.param_attack_for(3, 0) is None
    assert tm.param_attack_for(3, 2).kind == PARAM_TAMPER


def test_from_legacy_honest_attack_keeps_malicious_bookkeeping():
    """Legacy drivers allowed malicious={...} with attack=HONEST: nobody
    attacks, but History honesty accounting still counts those clients."""
    tm = ThreatModel.from_legacy({1, 3}, HONEST)
    assert tm.malicious == {1, 3}
    assert tm.attack_for(1, 0) == HONEST
    assert not tm.has_param_tamper
    assert not np.asarray(tm.attack_vec_for_clusters([[0, 1], [2, 3]], 0).code).any()


def test_build_drops_honest_entries_and_rejects_junk():
    tm = ThreatModel.build({0: HONEST, 1: Attack(LABEL_FLIP)})
    assert tm.malicious == {1}
    with pytest.raises(TypeError, match="ClientThreat"):
        ThreatModel.build({0: "label_flip"})


def test_resolve_threat_model_exclusivity():
    tm = ThreatModel.build({1: Attack(LABEL_FLIP)})
    assert resolve_threat_model(None, HONEST, tm) is tm
    legacy = resolve_threat_model({1}, Attack(LABEL_FLIP), None)
    assert legacy.malicious == {1}
    with pytest.raises(ValueError, match="not both"):
        resolve_threat_model({1}, Attack(LABEL_FLIP), tm)


def test_describe_is_json_serialisable():
    tm = ThreatModel.build({
        0: ClientThreat(Attack(BACKDOOR, target=3), ramp(4)),
        2: Attack(GRAD_NOISE),
    })
    manifest = json.loads(json.dumps(tm.describe()))
    assert manifest["0"]["attack"]["kind"] == BACKDOOR
    assert manifest["0"]["schedule"]["kind"] == "ramp"
    assert manifest["2"]["schedule"]["kind"] == "always"


def test_heterogeneous_grid_codes_and_lanes():
    grid = [[Attack(LABEL_FLIP, label_shift=2), HONEST],
            [Attack(GRAD_SCALE, grad_scale=7.0), Attack(BACKDOOR, target=9)]]
    av = attack_vec_grid(grid)
    assert av.code.shape == (2, 2)
    codes = np.asarray(av.code)
    assert codes[0, 1] == 0 and len({int(c) for c in codes.ravel()}) == 4
    assert np.asarray(av.shift)[0, 0] == 2
    assert np.asarray(av.grad_scale)[1, 0] == 7.0
    assert np.asarray(av.target)[1, 1] == 9
