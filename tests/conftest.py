import os

# Tests run on the single real CPU device (the 512-device override is
# exclusively for launch/dryrun.py, never set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def tiny_task():
    """Shared tiny MNIST split-CNN task for the fast tier: 4 clients, small
    shards, T-trimmed protocol configs — protocol behaviour is identical to
    the larger fixtures, just cheap enough to keep tier-1 under its 60 s
    budget."""
    from repro.core import from_cnn
    from repro.data import build_image_task

    data, cfg = build_image_task("mnist", m_clients=4, d_m=120, d_o=60,
                                 n_test=200, seed=0)
    return data, from_cnn(cfg)


@pytest.fixture(scope="session")
def tiny_pcfg():
    """Round-count-trimmed ProtocolConfig matching ``tiny_task``."""
    from repro.core import ProtocolConfig

    return ProtocolConfig(M=4, N=1, T=2, E=2, B=16, lr=0.05, seed=0)
