import os

# Tests run on the single real CPU device (the 512-device override is
# exclusively for launch/dryrun.py, never set globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
