"""Model substrate unit tests: chunked-vs-reference paths, decode-vs-forward
consistency, split/merge invariants for every architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.model import build_model

B, S, V = 2, 16, 97


def _batch():
    return {"tokens": jnp.arange(B * S).reshape(B, S) % V,
            "labels": jnp.ones((B, S), jnp.int32)}


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chunked_attention_matches_reference():
    cfg = attn.AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                          q_chunk=8)
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    full = attn.gqa_forward(p, cfg._replace(q_chunk=0), x)
    chunked = attn.gqa_forward(p, cfg, x)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked), atol=1e-5)


def test_sliding_window_masks_distant_tokens():
    cfg = attn.AttnConfig(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                          sliding_window=4)
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 32))
    y1 = attn.gqa_forward(p, cfg, x)
    # perturbing a token >window positions before the last must not change it
    x2 = x.at[:, 5].set(jax.random.normal(jax.random.PRNGKey(2), (1, 32)))
    y2 = attn.gqa_forward(p, cfg, x2)
    np.testing.assert_allclose(np.asarray(y1[:, -1]), np.asarray(y2[:, -1]),
                               atol=1e-5)


@pytest.mark.slow
def test_mla_decode_matches_forward():
    cfg = attn.MLAConfig(d_model=64, n_heads=4, head_dim=16, kv_lora_rank=32,
                         rope_dim=16)
    p = attn.mla_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64))
    full = attn.mla_forward(p, cfg, x)
    cache = attn.init_mla_cache(2, 12, cfg)
    outs = []
    for i in range(12):
        y, cache = attn.mla_decode(p, cfg, x[:, i:i+1], cache, i)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_moe_matches_loop_reference_with_ample_capacity():
    cfg = moe_mod.MoEConfig(d_model=32, d_expert=16, n_experts=4, top_k=2,
                            n_shared=1, capacity_factor=8.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    got, aux1 = moe_mod.moe_forward(p, cfg, x)
    expect, aux2 = moe_mod.moe_forward_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


@pytest.mark.slow
def test_moe_local_dispatch_matches_reference():
    """The shard-local dispatch formulation (§Perf) is numerically the same
    computation when capacity is ample."""
    cfg = moe_mod.MoEConfig(d_model=32, d_expert=16, n_experts=4, top_k=2,
                            n_shared=1, capacity_factor=8.0, shard=False,
                            shard_groups=4)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    got, aux1 = moe_mod.moe_forward(p, cfg, x)
    expect, aux2 = moe_mod.moe_forward_reference(
        p, cfg._replace(shard_groups=0), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expect), atol=1e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


@pytest.mark.slow
def test_moe_capacity_drops_tokens_gracefully():
    cfg = moe_mod.MoEConfig(d_model=16, d_expert=8, n_experts=2, top_k=1,
                            capacity_factor=0.25)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out, _ = moe_mod.moe_forward(p, cfg, x)
    assert out.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(out)))


def test_moe_router_weights_normalized():
    cfg = moe_mod.MoEConfig(d_model=16, d_expert=8, n_experts=4, top_k=2)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    w, ids, aux = moe_mod.route(p, cfg, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(8), atol=1e-5)
    assert bool(jnp.all(ids < cfg.n_experts))


# ---------------------------------------------------------------------------
# SSM / xLSTM: chunked parallel form == step-by-step recurrence
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mamba2_chunked_matches_recurrent():
    cfg = ssm_mod.SSMConfig(d_model=32, d_state=8, chunk=4)
    p = ssm_mod.mamba2_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    par = ssm_mod.mamba2_forward(p, cfg, u)
    rec = ssm_mod.mamba2_forward_reference(p, cfg, u)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec), atol=1e-4)


@pytest.mark.slow
def test_mlstm_chunked_matches_recurrent():
    cfg = xlstm_mod.XLSTMConfig(d_model=32, n_heads=2, chunk=4)
    p = xlstm_mod.mlstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    par = xlstm_mod.mlstm_forward(p, cfg, x)
    rec = xlstm_mod.mlstm_forward_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(par), np.asarray(rec), atol=1e-4)


def test_slstm_decode_matches_forward():
    cfg = xlstm_mod.XLSTMConfig(d_model=32, n_heads=2)
    p = xlstm_mod.slstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32)) * 0.5
    full = xlstm_mod.slstm_forward(p, cfg, x)
    cache = xlstm_mod.init_slstm_cache(2, cfg)
    outs = []
    for t in range(10):
        y, cache = xlstm_mod.slstm_decode(p, cfg, x[:, t:t+1], cache)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
# whole-model split / merge / decode invariants
# ---------------------------------------------------------------------------

FAMILY_CFGS = [
    ModelConfig(name="d", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=V, qk_norm=True, qkv_bias=True,
                sliding_window=8, global_every=2, cut_layer=1),
    ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=128, vocab=V, n_experts=4, top_k=2,
                d_expert=32, first_dense=1, capacity_factor=4.0, cut_layer=1),
    ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=0, vocab=V, ssm_state=16, ssm_chunk=8,
                cut_layer=1),
    ModelConfig(name="h", arch_type="hybrid", n_layers=5, d_model=64, n_heads=4,
                n_kv_heads=4, d_ff=0, vocab=V, ssm_state=16, ssm_chunk=8,
                attn_every=2, cut_layer=3),
]


@pytest.mark.parametrize("cfg", FAMILY_CFGS, ids=lambda c: c.arch_type)
@pytest.mark.slow
def test_split_forward_equals_full_forward(cfg):
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch()
    loss, metrics = m.loss(params, batch)
    gamma, phi = m.split_params(params)
    acts = m.client_forward(gamma, batch)
    loss2, metrics2 = m.ap_forward(phi, acts, batch)
    # client-side MoE aux loss is (correctly) not recoverable by the AP;
    # compare the LM component which must match exactly
    np.testing.assert_allclose(float(metrics["lm_loss"]),
                               float(metrics2["lm_loss"]), atol=1e-5)
    merged = m.merge_params(gamma, phi)
    loss3, _ = m.loss(merged, batch)
    np.testing.assert_allclose(float(loss), float(loss3), atol=1e-6)


@pytest.mark.parametrize("cfg", FAMILY_CFGS, ids=lambda c: c.arch_type)
@pytest.mark.slow
def test_decode_matches_forward(cfg):
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch()
    logits = m.logits(params, batch)
    cache = m.init_cache(B, S)
    outs = []
    for i in range(S):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, i:i+1], i)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits), atol=2e-4)


@pytest.mark.slow
def test_loss_chunking_matches_full():
    cfg = FAMILY_CFGS[0]
    import dataclasses
    cfg_c = dataclasses.replace(cfg, loss_chunk=4)
    m1, m2 = build_model(cfg), build_model(cfg_c)
    params = m1.init(jax.random.PRNGKey(0))
    l1, _ = m1.loss(params, _batch())
    l2, _ = m2.loss(params, _batch())
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


@pytest.mark.slow
def test_encdec_decode_matches_forward():
    """seamless-family: decoder decode w/ self-attn cache + cross-attn over
    encoder memory must match the full forward."""
    cfg = ModelConfig(name="ed", arch_type="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab=V,
                      n_enc_layers=2, cut_layer=1)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(B * 12).reshape(B, 12) % V,
             "labels": jnp.ones((B, 12), jnp.int32),
             "frames": 0.1 * jnp.ones((B, 8, 64))}
    logits = m.logits(params, batch)
    memory = m.encode(params, batch)
    cache = m.init_cache(B, 12)
    outs = []
    for i in range(12):
        lg, cache = m.decode_step(params, cache, batch["tokens"][:, i:i+1], i,
                                  memory=memory)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits), atol=2e-4)


def test_vlm_decode_after_patch_prefix():
    """internvl2-family: token decode continuing past an image-patch prefix
    processed by the forward path produces finite logits of the right shape."""
    cfg = ModelConfig(name="vv", arch_type="vlm", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=V,
                      n_prefix_tokens=4, cut_layer=1)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(B, 16)
    # feed patch embeddings through decode steps as pseudo-tokens is not the
    # serving path; instead decode plain tokens (image handled at prefill in
    # serving) — check cache decode works for the vlm plan
    logits, cache = m.decode_step(params, cache, jnp.zeros((B, 1), jnp.int32), 0)
    assert logits.shape == (B, 1, V)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
