"""Launch-layer unit tests: sharding rules, input specs, roofline math.
(The full 512-device dry-run runs via `python -m repro.launch.dryrun`; these
tests exercise the same code paths on a 1-device mesh.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.roofline import model_flops_for, roofline_terms
from repro.launch.shapes import SHAPES, applicable, shape_settings
from repro.launch.shardings import _spec_for_leaf
from repro.launch.steps import (apply_shape_settings, batch_struct,
                                decode_structs, input_specs,
                                make_pigeon_round_step, make_train_step)
from repro.models import build_model


def test_spec_rules_shard_expected_dims():
    ms = 16
    assert _spec_for_leaf("embed", (152064, 5120), ms) == P("model", None)
    assert _spec_for_leaf("head/w", (5120, 152064), ms) == P(None, "model")
    assert _spec_for_leaf("stacks/0/attn/wq/w", (48, 5120, 5120), ms) == \
        P(None, None, "model")
    assert _spec_for_leaf("stacks/0/attn/wo/w", (48, 5120, 5120), ms) == \
        P(None, "model", None)
    assert _spec_for_leaf("stacks/0/moe/gate", (48, 128, 2048, 768), ms) == \
        P(None, "model", None, None)
    # non-divisible dims fall through to replication
    assert _spec_for_leaf("stacks/0/attn/wq/w", (48, 5120, 40), ms) == \
        P(None, None, None)
    # norm scales replicate
    assert _spec_for_leaf("stacks/0/ln1/scale", (48, 5120), ms) == P(None, None)


def test_spec_rules_cluster_leading_dim():
    spec = _spec_for_leaf("embed", (2, 152064, 5120), 16,
                          cluster_axis="pod", cluster_dim=True)
    assert spec == P("pod", "model", None)


def test_pigeon_sweep_shardings_lead_with_seed_and_pod():
    """The sweep triple: params lead with the seed axis, batches with
    (seed, pod), and the shared set replicates across replicas but shards
    over the intra-replica data axis."""
    from jax.sharding import Mesh

    from repro.launch.shardings import pigeon_sweep_shardings

    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1, 1)
    mesh = Mesh(devs, ("seed", "pod", "data", "model"))
    params = {"head": {"w": jax.ShapeDtypeStruct((2, 16, 32), jnp.float32)},
              "norm": jax.ShapeDtypeStruct((2, 16), jnp.float32)}
    batches = {"tokens": jax.ShapeDtypeStruct((2, 2, 8, 4), jnp.int32)}
    val = {"tokens": jax.ShapeDtypeStruct((8, 4), jnp.int32)}
    p, b, v = pigeon_sweep_shardings(params, batches, val, mesh)
    assert p["head"]["w"].spec[0] == "seed"
    assert p["norm"].spec[0] == "seed"
    assert tuple(b["tokens"].spec)[:2] == ("seed", "pod")
    assert v["tokens"].spec == P("data", None)


def test_shape_applicability_matrix():
    runs = {(a, s) for a in list_archs() for s in SHAPES
            if applicable(a, s)[0]}
    assert len(runs) == 10 * 4 - 6        # six full-attention archs skip long_500k
    assert ("zamba2-1.2b", "long_500k") in runs
    assert ("qwen2.5-14b", "long_500k") not in runs


def test_batch_struct_shapes():
    cfg = apply_shape_settings(get_config("internvl2-26b"), SHAPES["train_4k"])
    bs = batch_struct(cfg, SHAPES["train_4k"])
    assert bs["patches"].shape == (256, 256, 6144)
    assert bs["tokens"].shape == (256, 4096 - 256)
    cfg2 = apply_shape_settings(get_config("qwen3-8b"), SHAPES["prefill_32k"])
    bs2 = batch_struct(cfg2, SHAPES["prefill_32k"])
    assert bs2["tokens"].shape == (32, 32768)


def test_decode_structs_cache_shapes():
    cfg = apply_shape_settings(get_config("deepseek-v2-lite-16b"),
                               SHAPES["decode_32k"])
    model = build_model(cfg)
    tokens, index, cache, memory = decode_structs(cfg, model, SHAPES["decode_32k"])
    assert tokens.shape == (128, 1)
    # MLA cache is compressed: latent rank 512 + rope 64, NOT 2*16*128
    flat = jax.tree.leaves(cache)
    latent = [l for l in flat if l.shape[-1] == 512]
    assert latent, [l.shape for l in flat]


def test_roofline_terms_math():
    rl = roofline_terms(197e12, 819e9, 50e9, chips=256, kind="train",
                        active_params=1_000_000, tokens=1000)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(1.0)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.model_flops == 6e9
    rl2 = roofline_terms(1, 819e9 * 2, 0, 256, "prefill", 10, 10)
    assert rl2.dominant == "memory"
    assert rl2.model_flops == 2 * 10 * 10


@pytest.mark.slow
def test_train_step_runs_on_one_device():
    cfg = get_smoke_config("qwen3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    step = jax.jit(make_train_step(model, 1e-3))
    new_params, loss = step(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.slow
def test_pigeon_round_step_selects_argmin():
    """The multi-pod program must pick the lowest-validation-loss cluster and
    broadcast its params to every slot."""
    cfg = get_smoke_config("h2o-danube-1.8b")
    model = build_model(cfg)
    r = 2
    keys = jax.random.split(jax.random.PRNGKey(0), r)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[model.init(k) for k in keys])
    batches = {"tokens": jnp.zeros((r, 2, 16), jnp.int32),
               "labels": jnp.zeros((r, 2, 16), jnp.int32)}
    val = {"tokens": jnp.ones((2, 16), jnp.int32),
           "labels": jnp.ones((2, 16), jnp.int32)}
    step = jax.jit(make_pigeon_round_step(model, lr=0.0))
    new_stacked, vlosses, sel = step(stacked, batches, val)
    assert vlosses.shape == (r,)
    assert int(sel) == int(jnp.argmin(vlosses))
    # every cluster slot now holds the winner's params
    for leaf in jax.tree.leaves(new_stacked):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   atol=1e-6)


def test_input_specs_lower_on_tiny_mesh():
    """input_specs must produce consistent (args, shardings) triples that
    jax.jit accepts — exercised on a 1x1 mesh so it runs on one CPU device."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("h2o-danube-1.8b")
    import dataclasses
    cfg = dataclasses.replace(cfg, name="h2o-danube-1.8b")
    with mesh:
        spec = input_specs(cfg, "train_4k", mesh)
        # just check tree structures line up
        assert len(spec.args) == len(spec.in_shardings)
        jax.tree.map(lambda a, s: None, spec.args[0], spec.in_shardings[0])


def test_pigeon_batch_split_shapes():
    """pigeon_batch_split gives each cluster global_batch/R."""
    import dataclasses
    from repro.launch.steps import input_specs
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = get_smoke_config("h2o-danube-1.8b")
    with mesh:
        spec_full = input_specs(cfg, "train_4k", mesh, pigeon_clusters=2)
        spec_half = input_specs(cfg, "train_4k", mesh, pigeon_clusters=2,
                                optimizations=("pigeon_batch_split",))
    b_full = spec_full.args[1]["tokens"].shape
    b_half = spec_half.args[1]["tokens"].shape
    assert b_full == (2, 256, 4096)
    assert b_half == (2, 128, 4096)


def test_largest_divisor_chunk():
    from repro.models.attention import largest_divisor_chunk
    assert largest_divisor_chunk(4096, 512) == 512
    assert largest_divisor_chunk(3840, 512) == 480
    assert largest_divisor_chunk(7, 16) == 7
    assert largest_divisor_chunk(30, 8) == 6
