"""Static-analysis subsystem tests: lint rules (positive + negative
fixtures per rule), jaxpr/HLO program audits against synthetic violations
of each invariant and against the real tiny-config RoundRunner programs,
budget baseline round-trips, the CLI gate's exit codes, and the telemetry
sink materialization regression."""
import json
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.findings import (Baseline, Report, assign_fingerprints,
                                     make_finding)
from repro.analysis.jaxpr_audit import (audit_fn, compiled_alias_pairs,
                                        entry_output_arity, find_callbacks,
                                        find_dtypes)
from repro.analysis.lints import lint_file


# ---------------------------------------------------------------------------
# lint-rule fixtures
# ---------------------------------------------------------------------------

def lint_source(tmp_path, source, relpath="src/repro/somefile.py"):
    """Write ``source`` at ``relpath`` under a synthetic repo root and lint
    that one file."""
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return lint_file(str(tmp_path), str(path))


def rules_of(findings):
    return sorted(f.rule for f in findings)


def test_prng_key_reuse_positive(tmp_path):
    findings = lint_source(tmp_path, """
        import jax

        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
        """)
    assert rules_of(findings) == ["prng-key-reuse"]
    assert "key" in findings[0].message


def test_prng_key_reuse_negative_split(tmp_path):
    findings = lint_source(tmp_path, """
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            return a + b

        def g(key, n):
            out = []
            for i in range(n):
                key, sub = jax.random.split(key)
                out.append(jax.random.normal(sub, (3,)))
            return out
        """)
    assert findings == []


def test_prng_key_reuse_branches_are_exclusive(tmp_path):
    findings = lint_source(tmp_path, """
        import jax

        def f(key, flag):
            if flag:
                a = jax.random.normal(key, (3,))
            else:
                a = jax.random.uniform(key, (3,))
            return a
        """)
    assert findings == []


def test_prng_key_reuse_across_loop_iterations(tmp_path):
    findings = lint_source(tmp_path, """
        import jax

        def f(key):
            out = []
            for _ in range(3):
                out.append(jax.random.normal(key, (3,)))
            return out
        """)
    assert rules_of(findings) == ["prng-key-reuse"]


def test_hidden_host_sync_positive(tmp_path):
    findings = lint_source(tmp_path, """
        import numpy as np

        def f(x):
            a = float(x)
            b = x.item()
            c = np.asarray(x)
            return a, b, c
        """, relpath="src/repro/core/engine.py")
    assert rules_of(findings) == ["hidden-host-sync"] * 3


def test_hidden_host_sync_negative(tmp_path):
    # whitelisted fetch helpers produce host values; other files are out of
    # the rule's scope entirely
    source = """
        import numpy as np
        from repro.selection import unpack_fetch

        def f(stacked):
            vec = unpack_fetch(np.asarray(stacked))
            return [float(v) for v in vec]
        """
    in_scope = lint_source(tmp_path, source,
                           relpath="src/repro/core/engine.py")
    # the np.asarray fetch itself is flagged (baseline territory); the
    # float() over the already-fetched values is not
    assert rules_of(in_scope) == ["hidden-host-sync"]
    assert "asarray" in in_scope[0].message
    out_of_scope = lint_source(tmp_path, """
        def f(x):
            return float(x)
        """, relpath="src/repro/launch/other.py")
    assert out_of_scope == []


def test_wall_clock_positive_and_exemption(tmp_path):
    source = """
        import time

        def f():
            return time.time()
        """
    assert rules_of(lint_source(tmp_path, source)) == ["wall-clock"]
    assert lint_source(tmp_path, source,
                       relpath="src/repro/telemetry/provenance.py") == []


def test_wall_clock_negative_perf_counter(tmp_path):
    findings = lint_source(tmp_path, """
        import time

        def f():
            return time.perf_counter()
        """)
    assert findings == []


def test_unseeded_np_random_positive(tmp_path):
    findings = lint_source(tmp_path, """
        import numpy as np

        NOISE = np.random.randn(4)
        """)
    assert rules_of(findings) == ["unseeded-np-random"]


def test_unseeded_np_random_negative(tmp_path):
    findings = lint_source(tmp_path, """
        import numpy as np

        rng = np.random.default_rng(0)
        NOISE = rng.normal(size=4)

        def f():
            return np.random.rand()  # function scope: not a module-load draw
        """)
    assert findings == []


def test_mutable_default_arg_positive(tmp_path):
    findings = lint_source(tmp_path, """
        def f(x, acc=[]):
            acc.append(x)
            return acc

        def g(x, table={}):
            return table
        """)
    assert rules_of(findings) == ["mutable-default-arg"] * 2


def test_mutable_default_arg_negative(tmp_path):
    findings = lint_source(tmp_path, """
        def f(x, acc=None, n=3, name="x"):
            acc = [] if acc is None else acc
            return acc
        """)
    assert findings == []


def test_parse_error_is_a_finding(tmp_path):
    findings = lint_source(tmp_path, "def broken(:\n")
    assert rules_of(findings) == ["parse-error"]


# ---------------------------------------------------------------------------
# findings engine: fingerprints + baseline
# ---------------------------------------------------------------------------

def test_fingerprint_survives_line_shift(tmp_path):
    body = """
        import time

        def f():
            return time.time()
        """
    a = lint_source(tmp_path, body, relpath="src/repro/a.py")
    shifted = "# one\n# two\n# three\n" + textwrap.dedent(body)
    b = lint_source(tmp_path, shifted, relpath="src/repro/a.py")
    a, b = assign_fingerprints(a), assign_fingerprints(b)
    assert a[0].line != b[0].line
    assert a[0].fingerprint == b[0].fingerprint


def test_duplicate_context_lines_get_distinct_fingerprints(tmp_path):
    findings = assign_fingerprints(lint_source(tmp_path, """
        import time

        def f():
            return time.time()

        def g():
            return time.time()
        """))
    assert len(findings) == 2
    assert findings[0].fingerprint != findings[1].fingerprint


def test_baseline_roundtrip_and_justification_enforcement(tmp_path):
    f1 = make_finding("wall-clock", "error", "src/repro/a.py", 4,
                      "msg", context="return time.time()")
    f2 = make_finding("wall-clock", "error", "src/repro/b.py", 9,
                      "msg", context="return time.time()")
    path = str(tmp_path / "lint_baseline.json")
    base = Baseline(path=path)
    base.add(f1, "intentional: wall-clock stamp for the run manifest")
    base.save()

    loaded = Baseline.load(path)
    assert loaded.suppresses(f1) and not loaded.suppresses(f2)

    report = Report(findings=[f1, f2], baseline=loaded)
    assert [f.fingerprint for f in report.open_findings] == [f2.fingerprint]

    # stripping the justification turns the suppression itself into a finding
    doc = json.load(open(path))
    doc["suppressions"][0]["justification"] = ""
    json.dump(doc, open(path, "w"))
    report = Report(findings=[f1, f2], baseline=Baseline.load(path))
    assert sorted(f.rule for f in report.open_findings) == [
        "unjustified-suppression", "wall-clock"]


def test_baseline_stale_detection(tmp_path):
    f1 = make_finding("wall-clock", "error", "src/repro/gone.py", 1, "msg",
                      context="time.time()")
    base = Baseline(path=str(tmp_path / "b.json"))
    base.add(f1, "why")
    assert base.stale([]) and base.stale([f1]) == []


# ---------------------------------------------------------------------------
# jaxpr/HLO audits: synthetic violations of each invariant
# ---------------------------------------------------------------------------

def test_audit_clean_function_passes():
    def clean(theta, x):
        return theta * x, jnp.sum(x)

    x = jnp.arange(4, dtype=jnp.float32)
    audit = audit_fn(clean, (x, x), name="t/clean", donate_argnums=(0,),
                     expected_donated=1, expected_fetch_leaves=1)
    assert audit.findings == []
    assert audit.donated_inputs == 1
    assert audit.aliased_outputs == 1
    assert audit.fetch_leaves == 1


def test_audit_flags_f64_weak_promotion():
    def leaky(x):
        # dtype=float is float64 once x64 is enabled: the classic weak leak
        return x + jnp.arange(x.shape[0], dtype=float)

    x = jnp.arange(4, dtype=jnp.float32)
    audit = audit_fn(leaky, (x,), name="t/leak", expected_fetch_leaves=1)
    assert "f64-in-program" in {f.rule for f in audit.findings}
    assert any("x64" in f.context for f in audit.findings)
    assert not jax.config.jax_enable_x64  # the retrace must not leak state


def test_audit_flags_host_callback():
    def chatty(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    x = jnp.arange(4, dtype=jnp.float32)
    audit = audit_fn(chatty, (x,), name="t/cb", expected_fetch_leaves=1,
                     x64_retrace=False)
    assert "host-callback-in-program" in {f.rule for f in audit.findings}


def test_audit_flags_lost_donation():
    def update(theta, x):
        return theta + x, jnp.sum(x)

    x = jnp.arange(4, dtype=jnp.float32)
    # donation intent says 1 carry leaf, but nothing is donated
    audit = audit_fn(update, (x, x), name="t/nodonate", donate_argnums=(),
                     expected_donated=1, expected_fetch_leaves=1)
    rules = {f.rule for f in audit.findings}
    # losing donation also breaks the fetch contract (the un-aliased carry
    # leaf becomes an extra fetched output)
    assert rules == {"donation-mismatch", "fetch-contract"}


def test_audit_flags_extra_fetch():
    def update(theta, x):
        # two non-aliased outputs where the contract pins one
        return theta + x, jnp.sum(x), jnp.max(x)

    x = jnp.arange(4, dtype=jnp.float32)
    audit = audit_fn(update, (x, x), name="t/extrafetch", donate_argnums=(0,),
                     expected_donated=1, expected_fetch_leaves=1)
    assert "fetch-contract" in {f.rule for f in audit.findings}
    assert audit.fetch_leaves == 2


def test_compiled_header_parsers():
    text = ("HloModule jit_f, is_scheduled=true, input_output_alias={ "
            "{0}: (0, {}, may-alias), {1}: (3, {}, may-alias) }, "
            "entry_computation_layout={(f32[2]{0}, f32[3,4]{1,0})->"
            "(f32[2]{0}, f32[3,4]{1,0}, f32[7]{0})}")
    assert compiled_alias_pairs(text) == [(0, 0), (1, 3)]
    assert entry_output_arity(text) == 3


def test_find_dtypes_descends_into_subjaxprs():
    def scanned(x):
        def body(c, _):
            return c, c.astype(np.float64) * np.float64(2.0)

        return jax.lax.scan(body, x, None, length=3)

    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(scanned)(jnp.float32(0.0))
    assert find_dtypes(jx)  # the f64 mul lives inside the scan body jaxpr
    assert find_callbacks(jx) == []


# ---------------------------------------------------------------------------
# real tiny-config RoundRunner program audit
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def analysis_ctx():
    from repro.analysis.programs import build_context
    return build_context()


def test_tiny_accept_program_is_clean(analysis_ctx):
    from repro.analysis.programs import CELLS, expected_counts
    cell = next(c for c in CELLS if c.name == "pigeon/accept@vmap")
    runner, (fn, args, donate) = cell.realize(analysis_ctx)
    expected_donated, expected_fetch = expected_counts(fn, args, donate)
    audit = audit_fn(fn, args, name=cell.name, donate_argnums=donate,
                     expected_donated=expected_donated,
                     expected_fetch_leaves=expected_fetch,
                     lowered=runner.lower("accept", *args))
    assert audit.findings == []
    theta_leaves = len(jax.tree.leaves(analysis_ctx.theta))
    assert audit.donated_inputs == theta_leaves
    assert audit.aliased_outputs == theta_leaves
    assert audit.fetch_leaves == 1      # the single stacked round vector
    assert audit.transfers.get("outfeed", 0) == 0
    assert audit.transfers.get("host_callback", 0) == 0


def test_quant_kernel_cell_is_clean(analysis_ctx):
    from repro.analysis.programs import CELLS, expected_counts
    cell = next(c for c in CELLS if c.name == "kernels/quant_dequant@int8")
    _, (fn, args, donate) = cell.realize(analysis_ctx)
    _, expected_fetch = expected_counts(fn, args, donate)
    audit = audit_fn(fn, args, name=cell.name,
                     expected_fetch_leaves=expected_fetch)
    assert audit.findings == []
    assert audit.fetch_leaves == 2      # dequantized message + row scales


# ---------------------------------------------------------------------------
# budget baselines
# ---------------------------------------------------------------------------

def test_budget_roundtrip_and_mismatch(tmp_path):
    from repro.analysis.budgets import compare_budget, merge_budget
    path = str(tmp_path / "programs.json")
    measured = {"pigeon/accept@vmap": {"eqns": 100, "fetch_leaves": 1}}

    findings, _ = compare_budget(path, measured, "program-budget")
    assert [f.rule for f in findings] == ["program-budget-baseline-missing"]

    merge_budget(path, measured)
    findings, notes = compare_budget(path, measured, "program-budget")
    assert findings == [] and notes == []

    drifted = {"pigeon/accept@vmap": {"eqns": 100, "fetch_leaves": 2}}
    findings, _ = compare_budget(path, drifted, "program-budget")
    assert [f.rule for f in findings] == ["program-budget-mismatch"]
    assert findings[0].severity == "error"
    assert "fetch_leaves: 1 -> 2" in findings[0].message

    new_cell = {"pigeon/accept@vmap+policy": {"eqns": 7}}
    findings, _ = compare_budget(path, new_cell, "program-budget")
    assert [f.rule for f in findings] == ["program-budget-cell-missing"]


def test_budget_merge_preserves_other_device_counts(tmp_path):
    from repro.analysis.budgets import load_budget, merge_budget
    path = str(tmp_path / "compile_counts.json")
    merge_budget(path, {"sweep/block1@sharded@d8": {"new_programs": 1}})
    merge_budget(path, {"sweep/block1@sharded@d1": {"new_programs": 1}})
    cells = load_budget(path)["cells"]
    assert set(cells) == {"sweep/block1@sharded@d1",
                          "sweep/block1@sharded@d8"}


def test_budget_jax_version_mismatch_downgrades(tmp_path):
    from repro.analysis.budgets import compare_budget, merge_budget
    path = str(tmp_path / "programs.json")
    merge_budget(path, {"cell": {"eqns": 1}})
    doc = json.load(open(path))
    doc["meta"]["jax"] = "0.0.0"
    json.dump(doc, open(path, "w"))
    findings, notes = compare_budget(path, {"cell": {"eqns": 2}},
                                     "program-budget")
    assert findings and findings[0].severity == "warning"
    assert notes and "0.0.0" in notes[0]


def test_checked_in_budgets_cover_all_driver_cells():
    """The acceptance contract: compile-count and transfer-count baselines
    for every driver x placement x block cell are committed."""
    from repro.analysis.budgets import DRIVER_CELLS, budget_path
    from repro.analysis.findings import repo_root
    root = repo_root()
    compiles = json.load(open(budget_path(root, "compile_counts.json")))
    for name, _ in DRIVER_CELLS:
        for suffix in ("@vmap", "@sharded@d1", "@sharded@d8"):
            assert f"{name}{suffix}" in compiles["cells"], (name, suffix)
        again = [k for k in compiles["cells"] if k.startswith(f"{name}@")
                 and "-again" in name]
        for k in again:
            assert compiles["cells"][k]["new_signatures"] == 0, k
    programs = json.load(open(budget_path(root, "programs.json")))
    for cell in ("pigeon/accept@vmap", "pigeon/accept_block@vmap",
                 "pigeon/round@vmap", "splitfed/accept@vmap",
                 "sweep/sweep@vmap", "kernels/quant_dequant@int8"):
        row = programs["cells"][cell]
        assert row["outfeed"] == 0 and row["host_callback"] == 0
        if row["donated_inputs"]:
            assert row["aliased_outputs"] == row["donated_inputs"]


# ---------------------------------------------------------------------------
# CLI gate
# ---------------------------------------------------------------------------

def make_synthetic_repo(tmp_path, violate=True):
    src = tmp_path / "src" / "repro"
    src.mkdir(parents=True)
    body = "import time\n\n\ndef f():\n    return time.time()\n" if violate \
        else "import time\n\n\ndef f():\n    return time.perf_counter()\n"
    (src / "mod.py").write_text(body)
    return tmp_path


def test_cli_lints_gate_exit_codes(tmp_path, capsys):
    from repro.analysis.cli import run
    root = make_synthetic_repo(tmp_path, violate=True)
    out_json = str(tmp_path / "findings.json")
    rc = run(["--check", "--layers", "lints", "--root", str(root),
              "--json", out_json])
    assert rc == 1
    doc = json.load(open(out_json))
    assert [f["rule"] for f in doc["open"]] == ["wall-clock"]
    assert "provenance" in doc
    capsys.readouterr()

    # baselining the finding (with a justification) flips the gate to green
    base = Baseline(path=str(root / "analysis" / "lint_baseline.json"))
    base.add(make_finding(**{k: v for k, v in doc["open"][0].items()
                             if k in ("rule", "severity", "path", "line",
                                      "message", "context")}),
             "synthetic fixture")
    base.save()
    assert run(["--check", "--layers", "lints", "--root", str(root)]) == 0
    capsys.readouterr()


def test_cli_clean_tree_and_flag_validation(tmp_path, capsys):
    from repro.analysis.cli import run
    root = make_synthetic_repo(tmp_path, violate=False)
    assert run(["--check", "--layers", "lints", "--root", str(root)]) == 0
    assert run(["--check", "--update-baselines"]) == 2
    assert run(["--layers", "nope"]) == 2
    capsys.readouterr()


def test_repo_tree_lints_are_clean_or_baselined():
    """The PR's own tree passes the lint layer (the CI gate's fast half)."""
    from repro.analysis.cli import LINT_BASELINE
    from repro.analysis.findings import repo_root
    from repro.analysis.lints import run_lints
    root = repo_root()
    report = Report(findings=run_lints(root),
                    baseline=Baseline.load(os.path.join(root, LINT_BASELINE)))
    assert report.open_findings == [], [f.located()
                                        for f in report.open_findings]


# ---------------------------------------------------------------------------
# telemetry sink materialization (satellite: one fetch per event, up front)
# ---------------------------------------------------------------------------

class _CountingArray:
    """Array-like that counts host materializations and per-element syncs."""

    def __init__(self, arr):
        self.arr = np.asarray(arr)
        self.asarray_calls = 0
        self.item_calls = 0

    def __array__(self, dtype=None, copy=None):
        self.asarray_calls += 1
        return self.arr if dtype is None else self.arr.astype(dtype)

    def item(self):
        self.item_calls += 1
        return self.arr.item()


def test_materialize_fetches_each_array_once():
    from repro.telemetry.sinks import materialize
    vec = _CountingArray(np.arange(3.0, dtype=np.float32))
    scalar = _CountingArray(np.float32(0.5))
    event = {"event": "round", "val_losses": vec, "nested": [{"acc": scalar}],
             "t": 3, "name": "run", "flag": True, "none": None}
    out = materialize(event)
    assert out["val_losses"] == [0.0, 1.0, 2.0]
    assert out["nested"][0]["acc"] == 0.5
    assert (out["t"], out["name"], out["flag"], out["none"]) == \
        (3, "run", True, None)
    assert vec.asarray_calls == 1 and vec.item_calls == 0
    assert scalar.asarray_calls == 1 and scalar.item_calls == 0
    json.dumps(out)  # fully JSON-native, no default= needed


def test_materialize_handles_jax_and_numpy_types():
    from repro.telemetry.sinks import materialize
    event = {"a": jnp.arange(2, dtype=jnp.int32), "b": np.float32(1.5),
             "c": (np.int64(2), [np.bool_(True)]), "d": jnp.float32(0.25)}
    out = materialize(event)
    assert out == {"a": [0, 1], "b": 1.5, "c": [2, [True]], "d": 0.25}
    assert isinstance(out["b"], float) and isinstance(out["c"][0], int)


def test_jsonl_sink_materializes_before_encoding(tmp_path):
    from repro.telemetry.sinks import JSONLSink, read_jsonl
    vec = _CountingArray(np.arange(4.0, dtype=np.float32))
    path = str(tmp_path / "events.jsonl")
    sink = JSONLSink(path)
    sink.emit({"event": "round", "val_losses": vec, "t": 0})
    sink.close()
    assert vec.asarray_calls == 1 and vec.item_calls == 0
    events = read_jsonl(path)
    assert events == [{"event": "round",
                       "val_losses": [0.0, 1.0, 2.0, 3.0], "t": 0}]
