"""Job-pool megabatching: pooled execution must be invisible to every job.

Contract (ISSUE: multi-job megabatching): each job's History — including
comm dicts and eval accuracies — is BIT-identical to running the same spec
alone through ``run_pigeon(engine="batched")``, across placements, block
sizes, threat-model mixes and mid-pool lane recycling; telemetry round
events carry the job tag and mirror the solo events; bucketing puts exactly
the program-shaping fields in the key.

The sharded placement sizes its job mesh to the device count, so these
tests run anywhere; the 8-virtual-device CI leg re-runs the file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to exercise real
multi-lane sharding.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (HONEST, LABEL_FLIP, Attack, ProtocolConfig,
                        run_pigeon)
from repro.core.jobs import (JobPool, JobSpec, bucket_key, plan_pool,
                             run_job_pool, validate_job)
from repro.telemetry import MemorySink, Telemetry

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the dedicated CI multi-device step sets it)")


def _pcfg(seed, t=4, eval_every=None, **kw):
    return ProtocolConfig(M=4, N=1, T=t, E=2, B=16, lr=0.05, seed=seed,
                          eval_every=t if eval_every is None else eval_every,
                          **kw)


def _specs(tiny_task, n=3, t=4, **kw):
    data, module = tiny_task
    return [JobSpec(name=f"job{s}", module=module, data=data,
                    pcfg=_pcfg(seed=s, t=t), **kw) for s in range(n)]


def assert_history_identical(h_pool, h_solo):
    assert len(h_pool.rounds) == len(h_solo.rounds)
    for a, b in zip(h_pool.rounds, h_solo.rounds):
        assert a == b      # bit-identical: comm dicts and test_acc included


def _solo(spec, block):
    return run_pigeon(spec.module, spec.data, spec.pcfg,
                      malicious=spec.malicious, attack=spec.attack,
                      threat_model=spec.threat_model,
                      selection=spec.selection, quant=spec.quant,
                      engine="batched", placement="vmap", block=block)


# ---------------------------------------------------------------------------
# pooled == solo, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("placement", ["vmap", "sharded"])
@pytest.mark.parametrize("block", [1, 2])
def test_pool_matches_solo(tiny_task, placement, block):
    specs = _specs(tiny_task, n=3, t=4)
    pooled = run_job_pool(specs, block=block, placement=placement)
    for s in specs:
        assert_history_identical(pooled[s.name], _solo(s, block))


def test_pool_mixed_threat_models(tiny_task):
    """Threat state is lane data, not program: an honest job and an attacked
    job share one bucket and both stay bit-identical to their solo runs."""
    data, module = tiny_task
    specs = [
        JobSpec(name="honest", module=module, data=data, pcfg=_pcfg(0)),
        JobSpec(name="flip", module=module, data=data, pcfg=_pcfg(1),
                malicious={1}, attack=Attack(LABEL_FLIP)),
    ]
    pool = JobPool(specs)
    assert len(pool.buckets()) == 1
    pooled = run_job_pool(specs, block=2)
    for s in specs:
        assert_history_identical(pooled[s.name], _solo(s, 2))


def test_pool_elastic_refill(tiny_task):
    """Fewer lanes than jobs + ragged horizons: finished jobs free their
    lane mid-pool and the queue refills it; every History still exact."""
    specs = [dataclasses.replace(s, pcfg=dataclasses.replace(
        s.pcfg, T=3 + i, eval_every=2)) for i, s in
        enumerate(_specs(tiny_task, n=3))]
    pooled = run_job_pool(specs, block=2, lanes=2)
    for s in specs:
        assert_history_identical(pooled[s.name], _solo(s, 2))


@multi_device
def test_pool_sharded_multi_device_refill(tiny_task):
    """Real multi-lane sharding (J=4 over the forced 8-device host) with
    block fusion; exact per-job Histories."""
    specs = _specs(tiny_task, n=4, t=4)
    pooled = run_job_pool(specs, block=2, placement="sharded")
    for s in specs:
        assert_history_identical(pooled[s.name], _solo(s, 2))


def test_pool_block1_matches_blockK(tiny_task):
    specs = _specs(tiny_task, n=2, t=4)
    h1 = run_job_pool(specs, block=1)
    hk = run_job_pool(specs, block=4)
    for s in specs:
        assert_history_identical(h1[s.name], hk[s.name])


def test_pool_checkpoint_resume(tiny_task, tmp_path):
    """Per-job crash-atomic checkpoints: a pool interrupted after its
    checkpoints resumes (in a pool) to the exact uninterrupted solo run."""
    data, module = tiny_task
    def mk(resume):
        return [JobSpec(name=f"job{s}", module=module, data=data,
                        pcfg=_pcfg(seed=s, t=4, eval_every=2),
                        checkpoint_path=str(tmp_path / f"job{s}.ckpt"),
                        checkpoint_every=2, resume=resume)
                for s in range(2)]
    short = [dataclasses.replace(s, pcfg=dataclasses.replace(s.pcfg, T=2))
             for s in mk(False)]
    run_job_pool(short, block=2)                    # writes round-1 ckpts
    pooled = run_job_pool(mk(True), block=2)        # resumes rounds 2..3
    for s in _specs(tiny_task, n=2):
        spec = dataclasses.replace(s, pcfg=_pcfg(seed=s.pcfg.seed, t=4,
                                                 eval_every=2))
        solo = _solo(spec, 2)
        resumed = pooled[s.name].rounds
        assert [r["round"] for r in resumed] == [2, 3]
        assert resumed == solo.rounds[2:]


# ---------------------------------------------------------------------------
# telemetry: job-tagged round events mirror the solo events
# ---------------------------------------------------------------------------

def test_pool_round_events_match_solo(tiny_task):
    specs = _specs(tiny_task, n=2, t=4)
    mem_pool = MemorySink()
    run_job_pool(specs, block=2, telemetry=Telemetry(sinks=(mem_pool,)))
    pool_rounds = mem_pool.of("round")
    for s in specs:
        mem_solo = MemorySink()
        run_pigeon(s.module, s.data, s.pcfg, engine="batched", block=2,
                   telemetry=Telemetry(sinks=(mem_solo,)))
        mine = [e for e in pool_rounds if e.get("job") == s.name]
        solo = mem_solo.of("round")
        assert len(mine) == len(solo) == s.pcfg.T
        for ep, es in zip(mine, solo):
            for k in ("t", "selected", "accepted", "detections",
                      "val_losses", "comm"):
                assert ep[k] == es[k], k
    blocks = mem_pool.of("pool_block")
    assert blocks and blocks[0]["lanes"] == 2
    assert blocks[-1]["jobs_done"] == len(specs)


# ---------------------------------------------------------------------------
# bucketing and validation
# ---------------------------------------------------------------------------

def test_bucket_rules(tiny_task):
    data, module = tiny_task
    base = JobSpec(name="a", module=module, data=data, pcfg=_pcfg(0))
    same = [
        dataclasses.replace(base, name="seed", pcfg=_pcfg(7)),
        dataclasses.replace(base, name="horizon", pcfg=_pcfg(0, t=9)),
        dataclasses.replace(base, name="attacked", malicious={1},
                            attack=Attack(LABEL_FLIP)),
    ]
    for other in same:
        assert bucket_key(base) == bucket_key(other), other.name
    diff = [
        dataclasses.replace(base, name="batch",
                            pcfg=dataclasses.replace(_pcfg(0), B=8)),
        dataclasses.replace(base, name="lr",
                            pcfg=dataclasses.replace(_pcfg(0), lr=0.01)),
        dataclasses.replace(base, name="quant", quant="int8"),
        dataclasses.replace(base, name="policy",
                            selection="median_of_means"),
    ]
    for other in diff:
        assert bucket_key(base) != bucket_key(other), other.name
    pool = JobPool([base] + same + diff)
    assert len(pool.buckets()) == 1 + len(diff)


def test_pool_multi_bucket_run(tiny_task):
    """Two incompatible shapes run as two buckets in one call; every job
    still bit-identical to solo."""
    data, module = tiny_task
    specs = [
        JobSpec(name="fast", module=module, data=data, pcfg=_pcfg(0)),
        JobSpec(name="slow", module=module, data=data,
                pcfg=dataclasses.replace(_pcfg(1), lr=0.01)),
    ]
    pooled = run_job_pool(specs, block=2)
    for s in specs:
        assert_history_identical(pooled[s.name], _solo(s, 2))


def test_pool_validation_errors(tiny_task):
    data, module = tiny_task
    base = JobSpec(name="a", module=module, data=data, pcfg=_pcfg(0))
    with pytest.raises(ValueError, match="duplicate job names"):
        JobPool([base, dataclasses.replace(base)])
    with pytest.raises(ValueError, match="empty job pool"):
        JobPool([])
    with pytest.raises(ValueError, match="not divisible"):
        validate_job(dataclasses.replace(
            base, pcfg=dataclasses.replace(_pcfg(0), M=5)))
    from repro.core.attacks import PARAM_TAMPER
    with pytest.raises(ValueError, match="param-tamper"):
        validate_job(dataclasses.replace(
            base, malicious={1}, attack=Attack(PARAM_TAMPER)))


def test_plan_pool_deterministic_schedule(tiny_task):
    """The whole-pool schedule is computable up front: K is the min over
    active lanes, sync rounds only ever end a block, and refills happen in
    queue order."""
    from repro.core.jobs import _init_job
    specs = [dataclasses.replace(s, pcfg=dataclasses.replace(
        s.pcfg, T=3 + i, eval_every=2)) for i, s in
        enumerate(_specs(tiny_task, n=3))]
    states = []
    for s in specs:
        policy, tm, pcfg = validate_job(s)
        states.append(_init_job(s, policy, tm, pcfg))
    plans = plan_pool(states, [0, 1, 2], lanes=2, block=2)
    for plan in plans:
        assert plan.k >= 1
        for lane, j in enumerate(plan.assign):
            if j < 0:
                continue
            st = states[j]
            # a sync round may only be the block's last executed round
            for dt in range(plan.k - 1):
                assert not st.is_sync(plan.t0s[lane] + dt)
    # every job's rounds are covered exactly once, in order
    seen = {i: [] for i in range(3)}
    for plan in plans:
        for lane, j in enumerate(plan.assign):
            if j >= 0:
                seen[j].extend(range(plan.t0s[lane],
                                     plan.t0s[lane] + plan.k))
    for i, st in enumerate(states):
        assert seen[i] == list(range(st.pcfg.T))
