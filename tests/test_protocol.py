"""Pigeon-SL protocol behaviour: selection, attacks, tamper detection,
Pigeon-SL+ throughput and the Table I communication accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACTIVATION, GRADIENT, HONEST, LABEL_FLIP, PARAM_TAMPER,
                        Attack, ClientData, ProtocolConfig, from_cnn,
                        run_pigeon, run_splitfed, run_vanilla_sl)
from repro.core import attacks as atk
from repro.core.protocol import _count_params, cut_width
from repro.core.split import client_update, sl_minibatch_grads
from repro.core.validation import check_handoff
from repro.data import build_image_task
from repro.models.cnn import MNIST_CNN


@pytest.fixture(scope="module")
def task():
    data, cfg = build_image_task("mnist", m_clients=4, d_m=200, d_o=100,
                                 n_test=400, seed=0)
    return data, from_cnn(cfg)


PCFG = ProtocolConfig(M=4, N=1, T=4, E=4, B=32, lr=0.05, seed=0)


@pytest.mark.slow
def test_pigeon_honest_learns(task):
    data, module = task
    hist = run_pigeon(module, data, PCFG, malicious=set())
    accs = [r["test_acc"] for r in hist.rounds]
    assert accs[-1] > 0.3, accs
    assert all(r["honest_cluster_exists"] for r in hist.rounds)


@pytest.mark.parametrize("attack", [Attack(LABEL_FLIP), Attack(GRADIENT),
                                    Attack(ACTIVATION)],
                         ids=lambda a: a.kind)
@pytest.mark.slow
def test_pigeon_resists_attacks(task, attack):
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=4)
    hist = run_pigeon(module, data, pcfg, malicious={1}, attack=attack, plus=True)
    accs = [r["test_acc"] for r in hist.rounds]
    assert accs[-1] > 0.3, accs


@pytest.mark.slow
def test_pigeon_selects_honest_under_label_flip(task):
    data, module = task
    hist = run_pigeon(module, data, PCFG, malicious={1}, attack=Attack(LABEL_FLIP))
    # the malicious cluster should essentially never win selection
    honest_sel = [r["selected_honest"] for r in hist.rounds]
    assert sum(honest_sel) >= len(honest_sel) - 1


def test_param_tamper_detected_and_rolled_back(task):
    """Force the III-C scenario: a malicious last client hands off tampered
    params; the handoff check must catch it."""
    data, module = task
    gamma, phi = module.init(jax.random.PRNGKey(0))
    x0 = jnp.asarray(data.x0)
    ref_acts = module.client_forward(gamma, x0)
    tampered = atk.tamper_params(Attack(PARAM_TAMPER), gamma, jax.random.PRNGKey(1))
    recv = module.client_forward(tampered, x0)
    ok, dist = check_handoff(ref_acts, [recv], tol=1e-4)
    assert not ok and dist > 1e-2
    ok2, dist2 = check_handoff(ref_acts, [module.client_forward(gamma, x0)])
    assert ok2 and dist2 < 1e-6


@pytest.mark.slow
def test_param_tamper_protocol_end_to_end(task):
    """With every client malicious-last possible (M=4, N=1 -> R=2 clusters of
    2), run with all-but-one malicious param-tamperers: detections must fire
    whenever a tampered cluster would be selected, and training still works."""
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=4)
    hist = run_pigeon(module, data, pcfg, malicious={0, 1, 3},
                      attack=Attack(PARAM_TAMPER))
    # pigeonhole violated here (3 > N=1) on purpose: but detection still
    # fires whenever a tampered handoff happens
    total_detections = sum(r["detections"] for r in hist.rounds)
    assert total_detections >= 1


def test_pigeon_plus_update_throughput(task):
    """Pigeon-SL+ must perform M client updates per round (matching vanilla
    SL), Pigeon-SL only M_bar = M/R."""
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=1)
    d_c = cut_width(module, module.init(jax.random.PRNGKey(0))[0], data.x0)
    h_plain = run_pigeon(module, data, pcfg, malicious=set())
    h_plus = run_pigeon(module, data, pcfg, malicious=set(), plus=True)
    per_sample = pcfg.E * pcfg.B * d_c
    # selected-cluster training activations: R*Mbar*E*B*d_c for the selection
    # phase; + (R-1)*Mbar*E*B*d_c extra for plus
    act_plain = h_plain.rounds[0]["comm"]["activation_floats"]
    act_plus = h_plus.rounds[0]["comm"]["activation_floats"]
    m_bar = pcfg.M // pcfg.R
    assert act_plain == pcfg.M * per_sample            # R clusters x Mbar
    assert act_plus == (2 * pcfg.M - m_bar) * per_sample


def test_comm_accounting_matches_table1(task):
    """Measured float counts must reproduce Table I's formulas."""
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=1)
    gamma0, _ = module.init(jax.random.PRNGKey(0))
    d_cl = _count_params(gamma0)
    d_c = cut_width(module, gamma0, data.x0)
    d_o = data.x0.shape[0]
    d_tilde = pcfg.E * pcfg.B

    hist = run_pigeon(module, data, pcfg, malicious=set())
    comm = hist.rounds[0]["comm"]
    # Table I total clients (Pigeon-SL): (M*D + 2R*Do)*d_c + M*d_CL
    assert comm["activation_floats"] == pcfg.M * d_tilde * d_c
    assert comm["validation_floats"] == 2 * pcfg.R * d_o * d_c
    assert comm["param_floats"] == pcfg.M * d_cl

    hist_v = run_vanilla_sl(module, data, pcfg, malicious=set())
    comm_v = hist_v.rounds[0]["comm"]
    assert comm_v["activation_floats"] == pcfg.M * d_tilde * d_c
    assert comm_v["param_floats"] == pcfg.M * d_cl
    assert comm_v["validation_floats"] == 0

    hist_p = run_pigeon(module, data, pcfg, malicious=set(), plus=True)
    comm_p = hist_p.rounds[0]["comm"]
    m_bar = pcfg.M // pcfg.R
    assert comm_p["activation_floats"] == (2 * pcfg.M - m_bar) * d_tilde * d_c
    assert comm_p["param_floats"] == (2 * pcfg.M - m_bar) * d_cl
    assert comm_p["validation_floats"] == 2 * pcfg.R * d_o * d_c


@pytest.mark.slow
def test_vanilla_sl_degrades_under_gradient_attack(task):
    """The paper's core motivation: one malicious client hurts vanilla SL
    more than Pigeon-SL+ (accuracy after the same number of rounds)."""
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=4, seed=3)
    mal = {1}
    h_v = run_vanilla_sl(module, data, pcfg, malicious=mal, attack=Attack(ACTIVATION))
    h_p = run_pigeon(module, data, pcfg, malicious=mal, attack=Attack(ACTIVATION),
                     plus=True)
    assert h_p.rounds[-1]["test_acc"] >= h_v.rounds[-1]["test_acc"] - 0.05


def test_splitfed_baseline_runs(task):
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=2, lr=0.5)   # paper: 10x SL lr
    hist = run_splitfed(module, data, pcfg, malicious={1}, attack=Attack(LABEL_FLIP))
    assert len(hist.rounds) == 2
    assert all("test_acc" in r for r in hist.rounds)


def test_attack_hooks_change_the_right_messages(task):
    """Label flip changes labels only; activation tamper changes the forward
    message; gradient tamper reverses the cut gradient."""
    data, module = task
    gamma, phi = module.init(jax.random.PRNGKey(0))
    x = jnp.asarray(data.x[0][:8])
    y = jnp.asarray(data.y[0][:8])
    key = jax.random.PRNGKey(0)

    g_h, p_h, l_h = sl_minibatch_grads(module, HONEST, gamma, phi, x, y, key)
    g_g, p_g, l_g = sl_minibatch_grads(module, Attack(GRADIENT), gamma, phi, x, y, key)
    # gradient attack reverses the client-side gradient exactly
    for a, b in zip(jax.tree.leaves(g_h), jax.tree.leaves(g_g)):
        np.testing.assert_allclose(np.asarray(a), -np.asarray(b), atol=1e-6)
    # ... but leaves the AP-side gradient untouched
    for a, b in zip(jax.tree.leaves(p_h), jax.tree.leaves(p_g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    # label flipping changes the loss (at random init the magnitude ordering
    # is not determined, so assert difference rather than direction)
    _, _, l_f = sl_minibatch_grads(module, Attack(LABEL_FLIP), gamma, phi, x, y, key)
    assert abs(float(l_f) - float(l_h)) > 1e-4


@pytest.mark.slow
def test_noniid_selection_degrades_gracefully(task):
    """Beyond-paper finding (see benchmarks/ablation_shared_set.py): under
    *mild* heterogeneity (alpha=2) the shared-set selection still mostly
    identifies honest clusters; under *harsh* skew (alpha=0.2) an
    honest-but-skewed cluster can lose the argmin to the poisoned one —
    the paper's i.i.d. assumption is load-bearing for the selection rule."""
    from repro.data import build_image_task, dirichlet_relabel
    data, cfg = build_image_task("mnist", m_clients=4, d_m=200, d_o=120,
                                 n_test=300, seed=4)
    data_mild = dirichlet_relabel(data, alpha=2.0, seed=4)
    # shards became skewed: per-client label diversity dropped
    data_harsh = dirichlet_relabel(data, alpha=0.2, seed=4)
    def mean_class_count(d):
        return np.mean([len(np.unique(d.y[i])) for i in range(4)])
    assert mean_class_count(data_harsh) < mean_class_count(data)
    module = from_cnn(cfg)
    pcfg = dataclasses.replace(PCFG, T=4)
    h_mild = run_pigeon(module, data_mild, pcfg, malicious={1},
                        attack=Attack(LABEL_FLIP))
    honest_mild = sum(r["selected_honest"] for r in h_mild.rounds)
    assert honest_mild >= 2, [r["selected_honest"] for r in h_mild.rounds]


@pytest.mark.slow
def test_pigeon_checkpoint_resume(task, tmp_path):
    """Protocol checkpoint/resume: resuming after round k reproduces the
    same final parameters trajectory (same cluster RNG stream)."""
    data, module = task
    path = str(tmp_path / "pigeon_ckpt")
    pcfg = dataclasses.replace(PCFG, T=3)
    h_full = run_pigeon(module, data, pcfg, malicious=set(),
                        checkpoint_path=path)
    # wipe nothing; resume from the saved round-2 checkpoint with T=4
    pcfg4 = dataclasses.replace(PCFG, T=4)
    h_res = run_pigeon(module, data, pcfg4, malicious=set(),
                       checkpoint_path=path, resume=True)
    # only the missing round runs
    assert len(h_res.rounds) == 1
    assert h_res.rounds[0]["round"] == 3


def test_evaluate_empty_test_set_returns_zero(task):
    """Regression: an empty test set used to crash with float(None) — the
    accumulator never initialised.  Zero correct out of zero is 0.0."""
    from repro.core.protocol import evaluate
    data, module = task
    gamma, phi = module.init(jax.random.PRNGKey(0))
    empty_x = data.x_test[:0]
    empty_y = data.y_test[:0]
    assert evaluate(module, gamma, phi, empty_x, empty_y) == 0.0


@pytest.mark.parametrize("engine", ["sequential", "batched"])
def test_rejected_plus_round_skips_subrounds(task, engine):
    """Regression: a rejected Pigeon-SL+ round used to run the R-1 extra
    sub-rounds anyway, handing the tamper-flagged selected cluster free
    turns.  With every client param-tampering, every round is rejected and
    the plus run must be identical to the plain run — zero sub-round client
    passes, same comm record, same key stream."""
    from repro.core import run_pigeon_plus
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=2, E=2)
    mal = set(range(pcfg.M))
    h = run_pigeon(module, data, pcfg, malicious=mal,
                   attack=Attack(PARAM_TAMPER), engine=engine)
    h_plus = run_pigeon_plus(module, data, pcfg, malicious=mal,
                             attack=Attack(PARAM_TAMPER), engine=engine)
    assert all(not r["accepted"] for r in h.rounds)
    for r, rp in zip(h.rounds, h_plus.rounds):
        assert not rp["accepted"]
        assert rp["comm"] == r["comm"]          # no extra client passes
        assert rp["selected"] == r["selected"]


def test_splitfed_records_comm(task):
    """Regression: run_splitfed never instantiated a CommMeter, so its
    History had no communication record at all."""
    data, module = task
    pcfg = dataclasses.replace(PCFG, T=2)
    h = run_splitfed(module, data, pcfg)
    d_c = cut_width(module, module.init(jax.random.PRNGKey(0))[0], data.x0)
    for r in h.rounds:
        comm = r["comm"]
        # M clients x E batches x 2 messages x B*d_c floats each round
        assert comm["activation_floats"] == pcfg.M * pcfg.E * pcfg.B * d_c
        assert comm["gradient_floats"] == comm["activation_floats"]
        assert comm["client_passes"] > 0
        assert comm["param_bytes"] > 0
