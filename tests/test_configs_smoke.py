"""Per-architecture smoke tests: instantiate the REDUCED variant of each
assigned architecture (<=2 layers, d_model<=512, <=4 experts), run one
forward and one train step on CPU, assert output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import build_model

# multi-config / multi-round end-to-end coverage: full-suite tier only
pytestmark = pytest.mark.slow

ARCHS = list_archs()


def _smoke_batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.arange(b * s).reshape(b, s) % cfg.vocab,
             "labels": (jnp.arange(b * s).reshape(b, s) + 1) % cfg.vocab}
    if cfg.arch_type == "vlm":
        batch["patches"] = 0.1 * jnp.ones((b, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.arch_type in ("audio", "encdec"):
        batch["frames"] = 0.1 * jnp.ones((b, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_is_exact(arch):
    """The full config matches the assigned spec (spot-check key fields)."""
    cfg = get_config(arch)
    spec = {
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec, f"{arch}: {got} != {spec}"
    assert cfg.source, f"{arch} missing source citation"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_reduced_bounds(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 or cfg.arch_type in ("hybrid",)
    assert cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)
    # forward
    logits = model.logits(params, batch)
    expect_s = batch["tokens"].shape[1]
    if cfg.arch_type == "vlm":
        expect_s += cfg.n_prefix_tokens
    assert logits.shape == (2, expect_s, cfg.vocab), logits.shape
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"
    # one SGD train step
    step = jax.jit(make_train_step(model, lr=1e-3))
    new_params, loss = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    # params changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step did not update params"
    # loss decreases over a few steps (sanity that gradients point downhill)
    p, prev = params, float(loss)
    for _ in range(3):
        p, l = step(p, batch)
    assert float(l) < prev + 0.5, f"{arch}: loss exploded {prev} -> {l}"


@pytest.mark.parametrize("arch", ["qwen3-8b", "zamba2-1.2b", "xlstm-1.3b",
                                  "deepseek-v2-lite-16b"])
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 8)
    logits, cache = model.decode_step(params, cache,
                                      jnp.zeros((2, 1), jnp.int32), 0)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
