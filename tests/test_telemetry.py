"""Telemetry subsystem: span tracing, sinks, metrics and the no-op-on-math
contract.

The load-bearing guarantees pinned here (see ``repro/telemetry/__init__``):

* spans nest per thread on the monotonic clock and fence device work at
  exit;
* the JSONL event log survives torn writes (crash mid-line) — reopening
  heals the tail and the reader skips unparseable lines;
* per-round metrics are populated from values the drivers already fetched —
  the ``round`` events mirror the History records exactly;
* telemetry is bit-identical-off on the math: enabling every sink and span
  changes neither the History nor the CommMeter across engines x placements
  x prefetch;
* the enabled batched path stays within a few percent of the disabled one.
"""
import json
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (HONEST, Attack, LABEL_FLIP, ProtocolConfig, Telemetry,
                        run_pigeon, run_splitfed, run_vanilla_sl)
from repro.telemetry import (DISABLED, NULL_SESSION, ConsoleSink, JSONLSink,
                             MemorySink, NullSession, Stopwatch,
                             TelemetrySession, provenance, read_jsonl,
                             resolve_telemetry)
from repro.telemetry.session import _BorrowedSession


def session_with_memory(**cfg_kwargs):
    mem = MemorySink()
    tel = Telemetry(sinks=(mem,), **cfg_kwargs).session("test")
    return tel, mem


# ---------------------------------------------------------------------------
# spans + timer
# ---------------------------------------------------------------------------

def test_stopwatch_elapsed_nonnegative():
    with Stopwatch() as sw:
        pass
    assert sw.elapsed >= 0.0


def test_span_nesting_paths_and_depth():
    tel, mem = session_with_memory()
    with tel.span("outer", round=3):
        with tel.span("inner"):
            pass
    tel.close()
    spans = mem.of("span")
    # children exit (and emit) before parents
    assert [s["name"] for s in spans] == ["inner", "outer"]
    inner, outer = spans
    assert inner["path"] == "outer/inner" and inner["depth"] == 1
    assert outer["path"] == "outer" and outer["depth"] == 0
    assert outer["round"] == 3
    assert inner["dur_s"] <= outer["dur_s"]


def test_span_fence_accepts_pytrees():
    tel, mem = session_with_memory()
    x = jnp.arange(8.0)
    with tel.span("step") as sp:
        y = x * 2
        sp.fence({"out": y, "nested": [y, x]})
    tel.close()
    (span,) = mem.of("span")
    assert span["name"] == "step" and span["dur_s"] >= 0


def test_span_error_annotated():
    tel, mem = session_with_memory()
    with pytest.raises(ValueError):
        with tel.span("doomed"):
            raise ValueError("boom")
    tel.close()
    (span,) = mem.of("span")
    assert span["error"] == "ValueError"


def test_spans_nest_independently_per_thread():
    tel, mem = session_with_memory()
    ready = threading.Event()

    def worker():
        with tel.span("worker.task"):
            ready.wait(5.0)

    th = threading.Thread(target=worker, name="feeder-sim")
    with tel.span("main.outer"):
        th.start()
        # the worker's span is open on ITS stack; ours must not see it
        with tel.span("main.inner"):
            pass
        ready.set()
        th.join(5.0)
    tel.close()
    by_name = {s["name"]: s for s in mem.of("span")}
    assert by_name["main.inner"]["path"] == "main.outer/main.inner"
    assert by_name["worker.task"]["path"] == "worker.task"
    assert by_name["worker.task"]["thread"] == "feeder-sim"


def test_spans_config_off_leaves_round_events():
    tel, mem = session_with_memory(spans=False)
    with tel.span("invisible"):
        pass
    tel.record_round(0, {"test_acc": 0.5})
    tel.close()
    assert mem.of("span") == []
    assert len(mem.of("round")) == 1


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_jsonl_roundtrip_and_torn_write_tolerance(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = JSONLSink(path)
    sink.emit({"event": "a", "i": 0})
    sink.emit({"event": "b", "i": 1})
    sink.close()
    # simulate a crash mid-write: torn final line without a newline
    with open(path, "a") as f:
        f.write('{"event": "c", "i":')
    # the tolerant reader skips the torn record
    assert [e["event"] for e in read_jsonl(path)] == ["a", "b"]
    # reopening heals the tail so appended events stay parseable
    sink2 = JSONLSink(path)
    sink2.emit({"event": "d", "i": 3})
    sink2.close()
    assert [e["event"] for e in read_jsonl(path)] == ["a", "b", "d"]


def test_jsonl_flushes_per_line(tmp_path):
    path = str(tmp_path / "live.jsonl")
    sink = JSONLSink(path)
    sink.emit({"event": "x"})
    # readable BEFORE close — the crash-tolerance contract
    assert [e["event"] for e in read_jsonl(path)] == ["x"]
    sink.close()


def test_console_sink_round_line(capsys):
    sink = ConsoleSink()
    sink.emit({"event": "round", "run": "pigeon", "t": 4, "test_acc": 0.875,
               "selected": 1, "selected_honest": True, "accepted": True,
               "detections": 0, "val_losses": [2.1, 2.2]})
    sink.emit({"event": "span", "name": "round.step", "dur_s": 0.1})
    out = capsys.readouterr().out
    lines = out.strip().splitlines()
    assert len(lines) == 1                      # spans don't hit the console
    assert "[pigeon] t=  4" in lines[0]
    assert "acc=0.8750" in lines[0] and "sel=1" in lines[0]
    assert "vloss=[2.1000,2.2000]" in lines[0]


def test_memory_sink_filters_by_kind():
    tel, mem = session_with_memory()
    tel.record_round(0, {"selected": 2})
    tel.close()
    assert [e["event"] for e in mem.events] == ["run_start", "round",
                                                "run_end"]
    assert mem.of("round")[0]["selected"] == 2


# ---------------------------------------------------------------------------
# session resolution / lifecycle
# ---------------------------------------------------------------------------

def test_resolve_disabled_returns_shared_null():
    assert resolve_telemetry(None) is NULL_SESSION
    assert resolve_telemetry(DISABLED) is NULL_SESSION
    assert resolve_telemetry(NULL_SESSION) is NULL_SESSION


def test_resolve_verbose_is_console_alias(capsys):
    tel = resolve_telemetry(None, verbose=True, run="x")
    assert isinstance(tel, TelemetrySession)
    tel.record_round(0, {"test_acc": 0.5})
    tel.close()
    assert "[x] t=  0 acc=0.5000" in capsys.readouterr().out


def test_resolve_borrowed_session_survives_driver_close():
    tel, mem = session_with_memory()
    borrowed = resolve_telemetry(tel)
    assert isinstance(borrowed, _BorrowedSession)
    borrowed.close()                      # driver-side close: must be a no-op
    tel.record_round(0, {})
    tel.close()
    kinds = [e["event"] for e in mem.events]
    assert kinds == ["run_start", "round", "run_end"]


def test_session_close_idempotent_and_emits_metrics():
    tel, mem = session_with_memory()
    tel.record_round(0, {"accepted": True, "selected_honest": True,
                         "detections": 2})
    tel.close()
    tel.close()
    (end,) = mem.of("run_end")
    counters = end["metrics"]["counters"]
    assert counters == {"rounds": 1, "rounds_accepted": 1, "detections": 2,
                        "honest_selections": 1}


def test_null_session_is_inert():
    s = NullSession()
    with s.span("x") as sp:
        sp.fence(jnp.zeros(2))
    s.record_round(0, {})
    s.profile_tick(0)
    s.close()
    assert not s.enabled


def test_provenance_stamp_keys():
    p = provenance(extra_key="v")
    for k in ("jax", "jaxlib", "python", "platform", "backend", "device_kind",
              "device_count", "cpu_count", "git_sha", "timestamp",
              "timestamp_utc"):
        assert k in p, k
    assert p["extra_key"] == "v"
    assert json.dumps(p)                  # JSON-serialisable throughout


# ---------------------------------------------------------------------------
# metrics from the stacked fetch: round events mirror History records
# ---------------------------------------------------------------------------

def test_round_events_mirror_history(tiny_task, tiny_pcfg):
    data, module = tiny_task
    mem = MemorySink()
    tel = Telemetry(sinks=(mem,))
    h = run_pigeon(module, data, tiny_pcfg, malicious={0},
                   attack=Attack(LABEL_FLIP), engine="batched", prefetch=1,
                   telemetry=tel)
    rounds = mem.of("round")
    assert len(rounds) == len(h.rounds) == tiny_pcfg.T
    for ev, rec in zip(rounds, h.rounds):
        assert ev["t"] == rec["round"]
        for k in ("selected", "accepted", "detections", "selected_honest",
                  "val_losses"):
            assert ev[k] == rec[k], k
        assert ev["comm"] == rec["comm"]
        assert ev["feeder_depth"] >= 0
    # spans cover the protocol phases the issue names
    names = {s["name"] for s in mem.of("span")}
    assert {"feeder.assemble", "round.feeder_wait", "round.step",
            "round.fetch", "round.select", "round.eval"} <= names


def test_trace_jsonl_from_three_round_run(tiny_task, tmp_path):
    data, module = tiny_task
    path = str(tmp_path / "run.jsonl")
    pcfg = ProtocolConfig(M=4, N=1, T=3, E=2, B=16, lr=0.05, seed=0)
    run_pigeon(module, data, pcfg, engine="batched", prefetch=1,
               telemetry=Telemetry(jsonl=path, jit_stats=True))
    evs = read_jsonl(path)
    assert evs[0]["event"] == "run_start"
    assert "git_sha" in evs[0]["provenance"]
    assert evs[-1]["event"] == "run_end"
    rounds = [e for e in evs if e["event"] == "round"]
    assert [r["t"] for r in rounds] == [0, 1, 2]
    jit = rounds[0]["jit"]
    assert jit["runners"] >= 1 and jit["programs"] >= 1
    assert jit["trace_compile_s"] >= 0


# ---------------------------------------------------------------------------
# bit-identity: telemetry on == telemetry off
# ---------------------------------------------------------------------------

def assert_history_identical(h_on, h_off):
    assert len(h_on.rounds) == len(h_off.rounds)
    for a, b in zip(h_on.rounds, h_off.rounds):
        assert a == b                    # bit-identical, comm dicts included


FULL_TELEMETRY = [
    pytest.param(lambda tmp: Telemetry(sinks=(MemorySink(),), jit_stats=True,
                                       jsonl=str(tmp / "t.jsonl")),
                 id="all-sinks"),
]


@pytest.mark.parametrize("engine,placement,prefetch", [
    ("sequential", "vmap", 0),
    ("batched", "vmap", 0),
    ("batched", "vmap", 1),
    ("batched", "sharded", 1),
])
def test_bit_identity_pigeon(tiny_task, tiny_pcfg, tmp_path, engine,
                             placement, prefetch):
    data, module = tiny_task
    kw = dict(malicious={0}, attack=Attack(LABEL_FLIP), engine=engine,
              placement=placement, prefetch=prefetch)
    h_off = run_pigeon(module, data, tiny_pcfg, **kw)
    h_on = run_pigeon(module, data, tiny_pcfg,
                      telemetry=Telemetry(sinks=(MemorySink(),),
                                          jit_stats=True,
                                          jsonl=str(tmp_path / "t.jsonl")),
                      **kw)
    assert_history_identical(h_on, h_off)


@pytest.mark.parametrize("engine,prefetch", [
    ("sequential", 0), ("batched", 1),
])
def test_bit_identity_splitfed(tiny_task, tiny_pcfg, tmp_path, engine,
                               prefetch):
    data, module = tiny_task
    kw = dict(malicious={0}, attack=Attack(LABEL_FLIP), engine=engine,
              prefetch=prefetch)
    h_off = run_splitfed(module, data, tiny_pcfg, **kw)
    h_on = run_splitfed(module, data, tiny_pcfg,
                        telemetry=Telemetry(sinks=(MemorySink(),)), **kw)
    assert_history_identical(h_on, h_off)


def test_bit_identity_vanilla(tiny_task, tiny_pcfg):
    data, module = tiny_task
    h_off = run_vanilla_sl(module, data, tiny_pcfg)
    h_on = run_vanilla_sl(module, data, tiny_pcfg,
                          telemetry=Telemetry(sinks=(MemorySink(),)))
    assert_history_identical(h_on, h_off)


def test_bit_identity_via_protocol_config(tiny_task, tiny_pcfg):
    """The ProtocolConfig.telemetry field is an equivalent plumbing route."""
    import dataclasses
    data, module = tiny_task
    h_off = run_pigeon(module, data, tiny_pcfg, engine="batched")
    pcfg_tel = dataclasses.replace(tiny_pcfg,
                                   telemetry=Telemetry(sinks=(MemorySink(),)))
    h_on = run_pigeon(module, data, pcfg_tel, engine="batched")
    assert_history_identical(h_on, h_off)


# ---------------------------------------------------------------------------
# overhead guard: enabled batched round within 5% of disabled
# ---------------------------------------------------------------------------

def test_telemetry_overhead_batched(tiny_task):
    data, module = tiny_task
    pcfg = ProtocolConfig(M=4, N=1, T=6, E=2, B=16, lr=0.05, seed=0,
                          eval_every=100)
    kw = dict(engine="batched", prefetch=1)
    tel = Telemetry(sinks=(MemorySink(),))
    # warm both paths (compile + allocator) before timing
    run_pigeon(module, data, pcfg, **kw)
    run_pigeon(module, data, pcfg, telemetry=tel, **kw)

    def best_of(n, **extra):
        best = float("inf")
        for _ in range(n):
            with Stopwatch() as sw:
                run_pigeon(module, data, pcfg, **extra, **kw)
            best = min(best, sw.elapsed)
        return best

    t_off = best_of(3)
    t_on = best_of(3, telemetry=tel)
    # 5% relative + a small absolute slack: sub-second CPU runs jitter by
    # scheduler noise far above telemetry's actual cost
    assert t_on <= t_off * 1.05 + 0.05, (t_on, t_off)


# ---------------------------------------------------------------------------
# launch-layer helpers
# ---------------------------------------------------------------------------

def test_instrument_step_passthrough_when_disabled():
    from repro.launch.steps import instrument_step
    fn = lambda x: x + 1  # noqa: E731
    assert instrument_step(fn, None, "s") is fn
    assert instrument_step(fn, NULL_SESSION, "s") is fn


def test_instrument_step_emits_span_per_call():
    from repro.launch.steps import instrument_step
    tel, mem = session_with_memory()
    step = instrument_step(lambda x: x * 2, tel, "serve.decode")
    assert float(step(jnp.float32(3))) == 6.0
    assert float(step(jnp.float32(4))) == 8.0
    tel.close()
    spans = mem.of("span")
    assert [s["name"] for s in spans] == ["serve.decode"] * 2
    assert [s["call"] for s in spans] == [0, 1]


def test_feeder_qsize_gauge(tiny_task, tiny_pcfg):
    from repro.data.pipeline import RoundFeeder
    with RoundFeeder(lambda t: t * 10, start=0, stop=0, depth=1) as f:
        assert f.qsize() == 0            # nothing scheduled
    with RoundFeeder(lambda t: t * 10, start=0, stop=4, depth=0) as f:
        assert f.qsize() == 0            # synchronous fallback
        assert f.get(0) == 0


# ---------------------------------------------------------------------------
# round-block execution: per-round events survive block-cadence host sync
# ---------------------------------------------------------------------------

def test_block_round_events_mirror_per_round(tiny_task, tiny_pcfg):
    """block=K still emits ONE round event per protocol round (replayed from
    the stacked block fetch), with the same payload the per-round loop
    records — telemetry consumers cannot tell the execution modes apart."""
    import dataclasses as _dc

    data, module = tiny_task
    pcfg = _dc.replace(tiny_pcfg, T=4, eval_every=10)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")

    mem_1, mem_4 = MemorySink(), MemorySink()
    run_pigeon(module, data, pcfg, telemetry=Telemetry(sinks=(mem_1,)),
               block=1, **kw)
    run_pigeon(module, data, pcfg, telemetry=Telemetry(sinks=(mem_4,)),
               block=4, **kw)

    rounds_1, rounds_4 = mem_1.of("round"), mem_4.of("round")
    assert [e["t"] for e in rounds_4] == [e["t"] for e in rounds_1] \
        == list(range(pcfg.T))
    for e1, e4 in zip(rounds_1, rounds_4):
        for k in ("selected", "accepted", "detections", "selected_honest",
                  "val_losses", "comm"):
            assert e1[k] == e4[k], k
    # block mode swaps the per-round step/fetch spans for block-grained ones
    names_4 = {s["name"] for s in mem_4.of("span")}
    assert {"block.assemble", "block.step", "block.fetch"} <= names_4


def test_block_recorded_in_run_start(tiny_task, tiny_pcfg, tmp_path):
    """The effective block size lands in the run_start provenance payload."""
    import dataclasses as _dc

    data, module = tiny_task
    pcfg = _dc.replace(tiny_pcfg, T=2, eval_every=10)
    path = str(tmp_path / "t.jsonl")
    run_pigeon(module, data, pcfg, engine="batched", block=2,
               telemetry=Telemetry(jsonl=path))
    evs = read_jsonl(path)
    start = [e for e in evs if e["event"] == "run_start"][0]
    assert start["block"] == 2


def test_compile_cache_stats_surface_in_jit_stats(tmp_path):
    """enable_compile_cache wires JAX's persistent cache; after clearing the
    in-process jit caches a re-jit loads from disk and the hit counters
    surface through telemetry's jit_cache_stats."""
    import jax

    from repro.core import enable_compile_cache
    from repro.core import compile_cache as cc
    from repro.telemetry.metrics import jit_cache_stats

    prev_dir, prev_hits, prev_misses = (cc._state["dir"], cc._state["hits"],
                                        cc._state["misses"])
    d = str(tmp_path / "xla_cache")
    try:
        assert enable_compile_cache(d) == d
        f = jax.jit(lambda x: x * 3 + 1)
        jax.block_until_ready(f(jnp.arange(4.0)))
        jax.clear_caches()                     # drop in-process executables
        f2 = jax.jit(lambda x: x * 3 + 1)
        jax.block_until_ready(f2(jnp.arange(4.0)))
        stats = jit_cache_stats()
        assert stats["persistent_cache_dir"] == d
        assert stats["persistent_cache_entries"] >= 1
        assert stats["persistent_cache_hits"] >= 1
        assert stats["persistent_cache_misses"] >= 1
    finally:
        jax.config.update("jax_compilation_cache_dir", None)
        cc._state["dir"] = prev_dir
        cc._state["hits"], cc._state["misses"] = prev_hits, prev_misses


def test_enable_compile_cache_disabled_without_dir(monkeypatch):
    from repro.core import compile_cache as cc
    monkeypatch.delenv(cc.ENV_VAR, raising=False)
    prev = cc._state["dir"]
    cc._state["dir"] = None
    try:
        assert cc.enable_compile_cache(None) is None    # no dir, no env: off
        stats = cc.compile_cache_stats()
        assert stats["persistent_cache_dir"] is None
        assert stats["persistent_cache_entries"] == 0
    finally:
        cc._state["dir"] = prev
