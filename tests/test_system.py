"""End-to-end behaviour tests for the paper's system.

The headline claims, at reduced scale:
  1. Pigeon-SL(+) trains to high accuracy with a malicious client present,
     where vanilla SL degrades or destabilises (Figs. 3-4).
  2. The protocol also works over a transformer LM (the framework
     integration: any splittable model runs the same protocol).
  3. More malicious clients (larger N) slow convergence (Figs. 5-6).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Attack, LABEL_FLIP, ACTIVATION, ProtocolConfig,
                        from_cnn, from_lm, run_pigeon, run_vanilla_sl)
from repro.data import build_image_task, build_lm_task
from repro.models import build_model
from repro.models.config import ModelConfig

# multi-config / multi-round end-to-end coverage: full-suite tier only
pytestmark = pytest.mark.slow


def test_e2e_pigeon_beats_vanilla_under_attack():
    data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=250, d_o=120,
                                     n_test=600, seed=1)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=4, N=1, T=5, E=5, B=32, lr=0.05, seed=1)
    mal = {2}
    attack = Attack(ACTIVATION)
    h_pigeon = run_pigeon(module, data, pcfg, malicious=mal, attack=attack,
                          plus=True)
    h_vanilla = run_vanilla_sl(module, data, pcfg, malicious=mal, attack=attack)
    acc_p = h_pigeon.rounds[-1]["test_acc"]
    acc_v = h_vanilla.rounds[-1]["test_acc"]
    assert acc_p > 0.5, f"pigeon failed to learn: {acc_p}"
    assert acc_p >= acc_v - 0.02, (acc_p, acc_v)


def test_e2e_protocol_over_transformer_lm():
    """The same protocol drives a (tiny) transformer LM split at its cut
    layer — the framework's integration point for the assigned archs."""
    vocab = 64
    cfg = ModelConfig(name="tiny-lm", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=vocab,
                      cut_layer=1)
    model = build_model(cfg)
    module = from_lm(model)
    data = build_lm_task(vocab=vocab, seq_len=32, m_clients=2, d_m=64, d_o=32,
                         n_test=32, seed=0)
    pcfg = ProtocolConfig(M=2, N=1, T=2, E=3, B=8, lr=5e-2, seed=0)
    hist = run_pigeon(module, data, pcfg, malicious={1},
                      attack=Attack(LABEL_FLIP))
    assert len(hist.rounds) == 2
    accs = [r["test_acc"] for r in hist.rounds]
    assert all(np.isfinite(a) for a in accs)
    # markov data is learnable: accuracy should be above uniform 1/64
    assert accs[-1] > 1.5 / vocab, accs


def test_e2e_larger_n_converges_slower():
    data, cnn_cfg = build_image_task("mnist", m_clients=6, d_m=200, d_o=100,
                                     n_test=500, seed=2)
    module = from_cnn(cnn_cfg)
    base = dict(M=6, T=4, E=4, B=32, lr=0.05, seed=2)
    accs = {}
    for n in (1, 2):
        pcfg = ProtocolConfig(N=n, **base)
        mal = set(range(n))
        hist = run_pigeon(module, data, pcfg, malicious=mal,
                          attack=Attack(LABEL_FLIP))
        accs[n] = [r["test_acc"] for r in hist.rounds]
    # with more clusters, fewer updates survive per round -> slower early curve
    assert np.mean(accs[2]) <= np.mean(accs[1]) + 0.05, accs
