"""Data pipeline, optimizer, checkpoint and HLO-analysis unit tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_checkpoint
from repro.data import build_image_task, build_lm_task, make_markov_tokens
from repro.launch.hlo_analysis import analyze_hlo
from repro.optim import adamw, clip_by_global_norm, sgd, warmup_cosine


def test_image_task_shapes():
    data, cfg = build_image_task("mnist", m_clients=3, d_m=50, d_o=20,
                                 n_test=40)
    assert data.x.shape == (3, 50, 28, 28, 1)
    assert data.y.shape == (3, 50)
    assert data.x0.shape == (20, 28, 28, 1)
    assert data.x_test.shape == (40, 28, 28, 1)
    assert set(np.unique(data.y)) <= set(range(10))


def test_image_task_is_learnable_and_consistent():
    d1, _ = build_image_task("mnist", m_clients=2, d_m=30, d_o=10, n_test=10,
                             seed=7)
    d2, _ = build_image_task("mnist", m_clients=2, d_m=30, d_o=10, n_test=10,
                             seed=7)
    np.testing.assert_array_equal(d1.x, d2.x)     # deterministic
    # same-class samples are closer than cross-class (templates dominate)
    y = d1.y[0]
    x = d1.x[0].reshape(30, -1)
    same, diff = [], []
    for i in range(20):
        for j in range(i + 1, 20):
            d = np.linalg.norm(x[i] - x[j])
            (same if y[i] == y[j] else diff).append(d)
    if same and diff:
        assert np.mean(same) < np.mean(diff)


def test_lm_task_shapes_and_shift():
    data = build_lm_task(vocab=32, seq_len=16, m_clients=2, d_m=8, d_o=4,
                         n_test=4)
    assert data.x.shape == (2, 8, 16)
    # labels are next-token shifted inputs
    np.testing.assert_array_equal(data.x[0, 0, 1:], data.y[0, 0, :-1])


def test_markov_tokens_are_predictable():
    """A strongly-peaked chain: repeated bigrams far above uniform chance."""
    from collections import Counter
    toks = make_markov_tokens(0, vocab=16, n_seqs=64, seq_len=32)
    total = toks.shape[0] * (toks.shape[1] - 1)
    bigrams = Counter(zip(toks[:, :-1].ravel(), toks[:, 1:].ravel()))
    top = bigrams.most_common(1)[0][1]
    assert top > total / (16 * 16) * 3


def test_sgd_and_adamw_minimize_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for opt in (sgd(0.1), sgd(0.05, momentum=0.9), adamw(0.2)):
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = jax.tree.map(lambda p, u: p + u, params, upd)
        assert float(loss(params)) < 1e-2


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0, abs=1e-5)
    assert float(sched(60)) < 1.0
    assert float(sched(200)) <= float(sched(60))


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_checkpoint_roundtrip_nested():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.int32), "d": (jnp.zeros(2), jnp.ones(1))}}
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "ck")
        save_checkpoint(p, tree, {"round": 3})
        back = restore_pytree(p, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hlo_analysis_multiplies_scan_bodies():
    """The analyzer must count while-loop bodies trip_count times (XLA's own
    cost_analysis counts them once — the reason this module exists)."""
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    flops = {}
    for layers in (2, 8):
        ws = jax.ShapeDtypeStruct((layers, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        compiled = jax.jit(f).lower(ws, x).compile()
        flops[layers] = analyze_hlo(compiled.as_text()).flops
    assert flops[8] == pytest.approx(4 * flops[2], rel=0.05), flops
    # absolute: 2*M*N*K per layer
    assert flops[8] == pytest.approx(8 * 2 * 8 * 64 * 64, rel=0.2)


def test_hlo_analysis_counts_collectives():
    # single-device programs have no collectives
    compiled = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    a = analyze_hlo(compiled.as_text())
    assert a.coll_bytes == 0
    assert a.flops == pytest.approx(2 * 32 * 32 * 32, rel=0.1)
