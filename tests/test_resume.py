"""On-stream checkpoint/resume + crash-atomic checkpoint writes.

The resume contract: a run checkpointed at round k and resumed reproduces the
uninterrupted run's remaining rounds BIT-FOR-BIT — same clusters, same
selections, same validation-loss floats, same test accuracy, same CommMeter
counts.  That requires the checkpoint to carry not just theta but the full
randomness-stream state (numpy bit-generator state + the protocol JAX key):
an uninterrupted run consumes ``sample_batch_idx`` draws every client turn
and splits the key per round/tamper-check, so replaying only the
``make_clusters`` draws (the historical fast-forward) went off-stream.

The durability contract: ``save_checkpoint`` writes both halves to temp
files and ``os.replace``s them (manifest last), and the halves share a
token — a torn checkpoint is *detected* (``CorruptCheckpointError``) and
``resume=True`` falls back to a fresh run instead of half-loading it.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.checkpoint import (CorruptCheckpointError, load_checkpoint,
                              protocol_state_metadata, restore_protocol_state,
                              restore_pytree, save_checkpoint)
from repro.core import (LABEL_FLIP, PARAM_TAMPER, Attack, run_pigeon,
                        run_pigeon_plus)


def assert_tail_bit_identical(h_full, h_res, start):
    """h_res must reproduce h_full.rounds[start:] exactly — float equality,
    not tolerance."""
    assert [r["round"] for r in h_res.rounds] == \
        [r["round"] for r in h_full.rounds[start:]]
    for ra, rb in zip(h_full.rounds[start:], h_res.rounds):
        assert ra["clusters"] == rb["clusters"]
        assert ra["selected"] == rb["selected"]
        assert ra["val_losses"] == rb["val_losses"]     # bit-identical floats
        assert ra["train_losses"] == rb["train_losses"]
        assert ra.get("test_acc") == rb.get("test_acc")
        assert ra["comm"] == rb["comm"]
        assert ra.get("detections") == rb.get("detections")


# ---------------------------------------------------------------------------
# resume equivalence: checkpoint at round t, resume, compare bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "batched"])
@pytest.mark.parametrize("runner", [run_pigeon, run_pigeon_plus],
                         ids=["pigeon", "pigeon_plus"])
def test_resume_is_on_stream(tiny_task, tiny_pcfg, tmp_path, engine, runner):
    """Resume at round 1 of a T=2 run: the first resumed round replays the
    clustering draw, every per-turn batch draw and every key split, so any
    off-stream state shows immediately as a cluster/loss mismatch."""
    data, module = tiny_task
    pcfg_full = dataclasses.replace(tiny_pcfg, T=2)
    pcfg_half = dataclasses.replace(tiny_pcfg, T=1)
    path = str(tmp_path / "ck")
    h_full = runner(module, data, pcfg_full, malicious={1},
                    attack=Attack(LABEL_FLIP), engine=engine)
    runner(module, data, pcfg_half, malicious={1}, attack=Attack(LABEL_FLIP),
           engine=engine, checkpoint_path=path)
    h_res = runner(module, data, pcfg_full, malicious={1},
                   attack=Attack(LABEL_FLIP), engine=engine,
                   checkpoint_path=path, resume=True)
    assert_tail_bit_identical(h_full, h_res, start=1)


def test_resume_is_on_stream_param_tamper(tiny_task, tiny_pcfg, tmp_path):
    """Param-tamper splits the protocol key at selection time — the resumed
    key stream must include those splits too."""
    data, module = tiny_task
    pcfg_full = dataclasses.replace(tiny_pcfg, T=2)
    pcfg_half = dataclasses.replace(tiny_pcfg, T=1)
    path = str(tmp_path / "ck")
    kw = dict(malicious={0, 1, 3}, attack=Attack(PARAM_TAMPER),
              engine="sequential")
    h_full = run_pigeon(module, data, pcfg_full, **kw)
    run_pigeon(module, data, pcfg_half, checkpoint_path=path, **kw)
    h_res = run_pigeon(module, data, pcfg_full, checkpoint_path=path,
                       resume=True, **kw)
    assert_tail_bit_identical(h_full, h_res, start=1)


def test_resume_with_prefetch_feeder_snapshot(tiny_task, tiny_pcfg, tmp_path):
    """With prefetch>0 the feeder consumes the streams ahead of the main
    loop, so the checkpoint must carry the feeder's per-round snapshot (taken
    right after round t's assembly), not the run-ahead live state."""
    data, module = tiny_task
    pcfg_full = dataclasses.replace(tiny_pcfg, T=3)
    pcfg_half = dataclasses.replace(tiny_pcfg, T=2)
    path = str(tmp_path / "ck")
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")
    h_full = run_pigeon(module, data, pcfg_full, **kw)
    run_pigeon(module, data, pcfg_half, prefetch=2, checkpoint_path=path, **kw)
    h_res = run_pigeon(module, data, pcfg_full, prefetch=2,
                       checkpoint_path=path, resume=True, **kw)
    assert_tail_bit_identical(h_full, h_res, start=2)


def test_protocol_state_metadata_roundtrips_through_json(tiny_pcfg):
    """The snapshot must survive the checkpoint's JSON serialization — numpy
    bit-generator states hold >64-bit ints, JAX keys are uint32 pairs."""
    import json

    import jax

    rng = np.random.default_rng(tiny_pcfg.seed)
    key = jax.random.PRNGKey(tiny_pcfg.seed)
    rng.integers(0, 100, size=17)                    # advance both streams
    key, _ = jax.random.split(key)
    meta = json.loads(json.dumps(protocol_state_metadata(rng, key)))
    rng2 = np.random.default_rng(999)
    key2 = restore_protocol_state(rng2, key, meta)
    np.testing.assert_array_equal(np.asarray(key2), np.asarray(key))
    np.testing.assert_array_equal(rng2.integers(0, 100, size=8),
                                  rng.integers(0, 100, size=8))


# ---------------------------------------------------------------------------
# crash-atomic writes + torn-checkpoint detection
# ---------------------------------------------------------------------------

def test_save_checkpoint_atomic_leaves_no_temp_residue(tmp_path):
    path = str(tmp_path / "ck")
    tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(3, dtype=np.float32)}
    save_checkpoint(path, tree, {"round": 4})
    assert sorted(os.listdir(tmp_path)) == ["ck.json", "ck.npz"]
    restored = restore_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    _, meta = load_checkpoint(path)
    assert meta == {"round": 4}


def test_torn_checkpoint_token_mismatch_detected(tmp_path):
    """Simulate the pre-atomic failure mode: the manifest of save A paired
    with the arrays of save B must be refused, not half-loaded."""
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(3)}, {"round": 0})
    with open(path + ".json") as f:
        stale_manifest = f.read()
    save_checkpoint(path, {"w": np.zeros(3)}, {"round": 1})
    with open(path + ".json", "w") as f:
        f.write(stale_manifest)
    with pytest.raises(CorruptCheckpointError, match="torn"):
        load_checkpoint(path)


def test_mixed_era_torn_checkpoint_detected(tmp_path):
    """One-sided token (new tokened arrays + legacy token-less manifest, the
    crash-over-an-upgraded-checkpoint window) must also be refused; only a
    fully legacy pair (no token on either side) loads."""
    import json

    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(3, dtype=np.float32)}, {"round": 1})
    with open(path + ".json") as f:
        meta = json.load(f)
    del meta["token"]                                 # legacy-style manifest
    with open(path + ".json", "w") as f:
        json.dump(meta, f)
    with pytest.raises(CorruptCheckpointError, match="torn"):
        load_checkpoint(path)


def test_truncated_arrays_detected(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(3)}, {"round": 0})
    with open(path + ".npz", "r+b") as f:
        f.truncate(16)
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(path)


def test_unparseable_manifest_detected(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, {"w": np.ones(3)}, {"round": 0})
    with open(path + ".json", "w") as f:
        f.write('{"names": ["w"], "tru')             # mid-write crash
    with pytest.raises(CorruptCheckpointError):
        load_checkpoint(path)


def test_resume_recovers_from_torn_checkpoint(tiny_task, tiny_pcfg, tmp_path):
    """resume=True against a corrupt checkpoint must warn and run the full
    trajectory from round 0 (identical to a fresh run), not half-load."""
    data, module = tiny_task
    path = str(tmp_path / "ck")
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")
    run_pigeon(module, data, tiny_pcfg, checkpoint_path=path, **kw)
    with open(path + ".npz", "r+b") as f:
        f.truncate(16)
    h_fresh = run_pigeon(module, data, tiny_pcfg, **kw)
    with pytest.warns(UserWarning, match="corrupt checkpoint"):
        h_res = run_pigeon(module, data, tiny_pcfg, checkpoint_path=path,
                           resume=True, **kw)
    assert_tail_bit_identical(h_fresh, h_res, start=0)


def test_resume_missing_checkpoint_starts_fresh(tiny_task, tiny_pcfg, tmp_path):
    data, module = tiny_task
    path = str(tmp_path / "never_saved")
    h = run_pigeon(module, data, tiny_pcfg, malicious={1},
                   attack=Attack(LABEL_FLIP), engine="batched",
                   checkpoint_path=path, resume=True)
    assert [r["round"] for r in h.rounds] == list(range(tiny_pcfg.T))


def test_resume_past_final_round_returns_restored_state(tiny_task, tiny_pcfg,
                                                        tmp_path):
    """Regression: resuming a checkpoint whose saved round already covers
    T-1 used to return an empty History silently.  It now warns and returns
    the restored final state with its test accuracy."""
    import warnings

    data, module = tiny_task
    path = str(tmp_path / "done_ckpt")
    h_full = run_pigeon(module, data, tiny_pcfg, checkpoint_path=path)
    assert len(h_full.rounds) == tiny_pcfg.T
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        h_res = run_pigeon(module, data, tiny_pcfg, checkpoint_path=path,
                           resume=True)
    assert any("nothing left to train" in str(w.message) for w in caught)
    assert len(h_res.rounds) == 1
    rec = h_res.rounds[0]
    assert rec["resumed_terminal"] is True
    assert rec["round"] == tiny_pcfg.T - 1
    assert rec["test_acc"] == h_full.rounds[-1]["test_acc"]


# ---------------------------------------------------------------------------
# round-block checkpointing: block-cadence writes + cross-mode resume
# ---------------------------------------------------------------------------

def test_resume_block_mode_is_on_stream(tiny_task, tiny_pcfg, tmp_path):
    """A block-mode run checkpoints at block boundaries (checkpoint rounds
    are sync rounds, so blocks END there); resuming it mid-trajectory must
    reproduce the uninterrupted PER-ROUND run's tail bit-for-bit — one
    stream snapshot per block is enough because the fused path splits no
    keys after assembly."""
    data, module = tiny_task
    pcfg_full = dataclasses.replace(tiny_pcfg, T=4, eval_every=10)
    pcfg_half = dataclasses.replace(tiny_pcfg, T=2, eval_every=10)
    path = str(tmp_path / "ck")
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")
    h_full = run_pigeon(module, data, pcfg_full, **kw)          # block=1 ref
    run_pigeon(module, data, pcfg_half, checkpoint_path=path,
               checkpoint_every=2, block=2, **kw)
    h_res = run_pigeon(module, data, pcfg_full, checkpoint_path=path,
                       checkpoint_every=2, block=2, resume=True, **kw)
    assert_tail_bit_identical(h_full, h_res, start=2)


def test_resume_across_block_modes(tiny_task, tiny_pcfg, tmp_path):
    """Checkpoints are mode-agnostic: a block-written checkpoint resumes
    under per-round execution and a per-round checkpoint resumes under
    blocks, both bit-identical to the uninterrupted reference."""
    data, module = tiny_task
    pcfg_full = dataclasses.replace(tiny_pcfg, T=4, eval_every=10)
    pcfg_half = dataclasses.replace(tiny_pcfg, T=2, eval_every=10)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")
    h_full = run_pigeon(module, data, pcfg_full, **kw)

    path_b = str(tmp_path / "ck_block")        # block-written -> per-round
    run_pigeon(module, data, pcfg_half, checkpoint_path=path_b,
               checkpoint_every=2, block=2, **kw)
    h_res = run_pigeon(module, data, pcfg_full, checkpoint_path=path_b,
                       resume=True, **kw)
    assert_tail_bit_identical(h_full, h_res, start=2)

    path_r = str(tmp_path / "ck_round")        # per-round -> block resume
    run_pigeon(module, data, pcfg_half, checkpoint_path=path_r, **kw)
    h_res2 = run_pigeon(module, data, pcfg_full, checkpoint_path=path_r,
                        checkpoint_every=2, block=2, resume=True, **kw)
    assert_tail_bit_identical(h_full, h_res2, start=2)


def test_checkpoint_every_thins_per_round_writes(tiny_task, tiny_pcfg,
                                                 tmp_path, monkeypatch):
    """checkpoint_every=k writes only the due rounds ((t+1) % k == 0, plus
    the final round) instead of every round — the block-cadence knob also
    thins per-round runs."""
    import repro.checkpoint as checkpoint_mod

    # the driver imports save_checkpoint lazily at each write, so patch the
    # source module
    written = []
    real_save = checkpoint_mod.save_checkpoint

    def counting_save(path, tree, meta):
        written.append(meta["round"])
        return real_save(path, tree, meta)

    monkeypatch.setattr(checkpoint_mod, "save_checkpoint", counting_save)
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, T=4, eval_every=10)
    path = str(tmp_path / "ck")
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched")
    run_pigeon(module, data, pcfg, checkpoint_path=path, checkpoint_every=2,
               **kw)
    assert written == [1, 3]                   # t=1 due, t=3 due+final
    _, meta = load_checkpoint(path)
    assert meta["round"] == pcfg.T - 1
