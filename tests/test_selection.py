"""Selection subsystem: policy units, the masked acceptance cascade, and the
fused-vs-host equivalence contract.

The load-bearing guarantee: ``selection="argmin"`` (the default) run through
the fused on-device cascade is bit-identical — History records (val_losses,
train_losses, selected, detections, accepted, test_acc) and CommMeter counts
— to the host-side reference cascade (``repro.selection.select_host``, the
pre-refactor ``run_pigeon`` loop), under both engines and both placements.
The new policies are checked for the behaviours they exist for: trimmed
drops score outliers, median_of_means resists poisoned validation shards,
and loss_plus_distance flags the stealth/replay message anomalies that evade
pure loss argmin.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Attack, LABEL_FLIP, PARAM_TAMPER, REPLAY,
                        ProtocolConfig, ThreatModel, from_cnn, run_pigeon,
                        run_pigeon_sweep, run_splitfed, stealth)
from repro.core.protocol import evaluate
from repro.core.validation import select_cluster
from repro.selection import (LossPlusDistancePolicy, MedianOfMeansPolicy,
                             ScoreContext, SelectionPolicy, TrimmedPolicy,
                             effective_shards, masked_first_accept,
                             pack_fetch, resolve_policy, robust_z,
                             selection_policies, unpack_fetch)

POLICIES = ("argmin", "median_of_means", "loss_plus_distance", "trimmed")


# ---------------------------------------------------------------------------
# units: registry, cascade, policy stages
# ---------------------------------------------------------------------------

def test_registry_resolves_names_and_instances():
    assert set(POLICIES) <= set(selection_policies())
    assert resolve_policy("argmin") is resolve_policy(None)
    custom = LossPlusDistancePolicy(weight=2.0)
    assert resolve_policy(custom) is custom
    with pytest.raises(ValueError, match="unknown selection policy"):
        resolve_policy("warp")


def test_select_cluster_is_host_argmin():
    assert select_cluster([3.0, 1.0, 2.0]) == 1
    assert select_cluster([1.0, 1.0]) == 0          # ties toward lower index
    assert isinstance(select_cluster(np.float32([2.0, 1.5])), int)


def test_masked_first_accept_walks_rank_order():
    scores = jnp.asarray([3.0, 1.0, 2.0, 4.0])
    ones = jnp.ones(4, bool)
    # everything passes: plain argmin
    sel, det, acc = masked_first_accept(scores, ones, ones)
    assert (int(sel), int(det), bool(acc)) == (1, 0, True)
    # rank-0 candidate fails verification: reselect the runner-up, 1 detection
    passed = jnp.asarray([True, False, True, True])
    sel, det, acc = masked_first_accept(scores, ones, passed)
    assert (int(sel), int(det), bool(acc)) == (2, 1, True)
    # nothing passes: rollback, selected still reports the argmin
    sel, det, acc = masked_first_accept(scores, ones, jnp.zeros(4, bool))
    assert (int(sel), int(det), bool(acc)) == (1, 4, False)


def test_masked_first_accept_respects_eligibility():
    scores = jnp.asarray([3.0, 1.0, 2.0, 4.0])
    elig = jnp.asarray([True, False, True, True])   # trim the argmin
    sel, det, acc = masked_first_accept(scores, elig, jnp.ones(4, bool))
    assert (int(sel), int(det), bool(acc)) == (2, 0, True)
    # ineligible candidates are never visited: failures among them don't
    # count as detections, and an all-fail walk counts only eligible visits
    sel, det, acc = masked_first_accept(scores, elig, jnp.zeros(4, bool))
    assert (int(det), bool(acc)) == (3, False)
    # all-ineligible falls back to all-eligible
    sel, det, acc = masked_first_accept(scores, jnp.zeros(4, bool),
                                        jnp.ones(4, bool))
    assert (int(sel), bool(acc)) == (1, True)


def test_pack_unpack_fetch_roundtrip():
    fetch = pack_fetch(jnp.asarray([1.0, 2.0]), jnp.asarray([3.0, 4.0]),
                       jnp.int32(1), jnp.int32(0), jnp.asarray(True))
    vl, tl, sel, det, acc = unpack_fetch(np.asarray(fetch), 2)
    assert list(vl) == [1.0, 2.0] and list(tl) == [3.0, 4.0]
    assert (sel, det, acc) == (1, 0, True)


def test_effective_shards_divides():
    assert effective_shards(4, 100) == 4
    assert effective_shards(4, 1500) == 4
    assert effective_shards(7, 100) == 5
    assert effective_shards(3, 7) == 1


def test_robust_z_degenerate_is_zero():
    z = robust_z(jnp.zeros(8))
    np.testing.assert_allclose(np.asarray(z), 0.0)


def test_trimmed_drops_low_outlier():
    # one suspiciously low loss among an otherwise tight field
    vl = jnp.asarray([1.00, 1.02, 0.10, 1.01])
    pol = TrimmedPolicy(z_tol=3.0)
    ctx = ScoreContext(vlosses=vl)
    elig = np.asarray(pol.eligible(ctx, pol.score(ctx)))
    assert not elig[2] and elig[[0, 1, 3]].all()


def test_median_of_means_resists_poisoned_shard():
    # cluster 0: great on 3 shards, catastrophic on one (targeted poisoning
    # of a validation slice); cluster 1: uniformly mediocre.  Plain mean
    # picks 0 at the wrong moments; the shard median picks 1.
    shard = jnp.asarray([[0.1, 0.1, 0.1, 9.0],
                         [0.5, 0.5, 0.5, 0.5]])
    ctx = ScoreContext(vlosses=jnp.mean(shard, axis=1), shard_losses=shard)
    scores = np.asarray(MedianOfMeansPolicy(shards=4).score(ctx))
    assert scores[0] < scores[1]            # median ignores the bad shard
    assert float(jnp.mean(shard, axis=1)[0]) > float(jnp.mean(shard, axis=1)[1])


def test_loss_plus_distance_flags_message_anomalies():
    """Synthetic message statistics: a replay client (dispersion collapse)
    and a stealth client (support residual) must blow up their clusters'
    scores even when those clusters hold the loss argmin."""
    vl = jnp.asarray([0.9, 1.0, 1.1, 1.05])        # poisoned clusters win on loss
    disp = np.full((4, 2), 0.5) + np.random.default_rng(0).normal(0, 0.02, (4, 2))
    sup = np.zeros((4, 2))
    disp[0, 1] = 0.0                               # replay in cluster 0
    sup[1, 0] = 0.02                               # stealth in cluster 1
    stats = jnp.asarray(np.stack([disp, sup], axis=-1), dtype=jnp.float32)
    pol = LossPlusDistancePolicy()
    scores = np.asarray(pol.score(ScoreContext(vlosses=vl, message_stats=stats)))
    assert scores[0] > max(scores[2], scores[3])
    assert scores[1] > max(scores[2], scores[3])
    assert int(np.argmin(scores)) in (2, 3)


# ---------------------------------------------------------------------------
# fused-vs-host equivalence (the bit-identity contract)
# ---------------------------------------------------------------------------

def assert_records_identical(h_a, h_b, keys=("clusters", "val_losses",
                                             "train_losses", "selected",
                                             "accepted", "selected_honest",
                                             "detections", "comm",
                                             "test_acc")):
    assert len(h_a.rounds) == len(h_b.rounds)
    for ra, rb in zip(h_a.rounds, h_b.rounds):
        for k in keys:
            if k in ra or k in rb:
                assert ra[k] == rb[k], (k, ra["round"], ra[k], rb[k])


@pytest.mark.parametrize("placement", ["vmap", "sharded"])
@pytest.mark.parametrize("selection", POLICIES)
def test_fused_cascade_identical_to_host_pigeon(tiny_task, tiny_pcfg,
                                                placement, selection):
    """The compiled score->rank->verify->commit cascade must reproduce the
    host reference selector exactly — selection, History floats, CommMeter —
    for every policy under both placements.  (Bit-identity is the argmin
    acceptance criterion; the stricter all-policy check documents that the
    fused and host cascades share one decision procedure.)"""
    data, module = tiny_task
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              placement=placement, selection=selection)
    h_fused = run_pigeon(module, data, tiny_pcfg, **kw)
    h_host = run_pigeon(module, data, tiny_pcfg, _force_host_selection=True,
                        **kw)
    assert_records_identical(h_fused, h_host)


def test_fused_argmin_matches_sequential_oracle(tiny_task, tiny_pcfg):
    """Default-path smoke against the sequential oracle: same selections and
    bit-identical comm counts (losses agree to float tolerance, as between
    the two engines before the refactor)."""
    data, module = tiny_task
    h_seq = run_pigeon(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="sequential")
    h_fused = run_pigeon(module, data, tiny_pcfg, malicious={1},
                         attack=Attack(LABEL_FLIP), engine="batched")
    for rs, rb in zip(h_seq.rounds, h_fused.rounds):
        assert rs["selected"] == rb["selected"]
        assert rs["accepted"] and rb["accepted"]
        assert rs["comm"] == rb["comm"]
        np.testing.assert_allclose(rs["val_losses"], rb["val_losses"],
                                   rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("selection", POLICIES)
def test_policies_agree_across_engines(tiny_task, tiny_pcfg, selection):
    """Every policy must pick the same clusters on the sequential oracle and
    the fused batched path (scores equal within float tolerance)."""
    data, module = tiny_task
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), selection=selection)
    h_seq = run_pigeon(module, data, tiny_pcfg, engine="sequential", **kw)
    h_bat = run_pigeon(module, data, tiny_pcfg, engine="batched", **kw)
    assert [r["selected"] for r in h_seq.rounds] == \
        [r["selected"] for r in h_bat.rounds]


def test_param_tamper_rollback_records_accepted_false(tiny_task, tiny_pcfg):
    """The all-tampered round keeps theta^t: it must record accepted=False
    and must NOT charge the R*d_CL broadcast that never happens (the
    pre-subsystem accounting bug), under both engines identically."""
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, T=3)
    kw = dict(malicious={0, 1, 3}, attack=Attack(PARAM_TAMPER))
    h_seq = run_pigeon(module, data, pcfg, engine="sequential", **kw)
    h_bat = run_pigeon(module, data, pcfg, engine="batched", **kw)
    assert_records_identical(h_seq, h_bat,
                             keys=("selected", "accepted", "detections",
                                   "comm"))
    rejected = [r for r in h_bat.rounds if not r["accepted"]]
    accepted = [r for r in h_bat.rounds if r["accepted"]]
    assert rejected, "expected at least one all-tampered round"
    assert accepted, "expected at least one accepted round"
    for r in rejected:
        assert r["detections"] == pcfg.R
        assert r["selected"] == int(np.argmin(r["val_losses"]))
    # the phantom broadcast is gone: a rejected round charges only the
    # intra-cluster handoffs — exactly R*d_CL less than an accepted round
    gamma0, _ = module.init(jax.random.PRNGKey(0))
    from repro.core.protocol import _count_params
    d_cl = _count_params(gamma0)
    assert (accepted[0]["comm"]["param_floats"]
            - rejected[0]["comm"]["param_floats"]) == pcfg.R * d_cl


@pytest.mark.parametrize("selection", ["argmin", "median_of_means",
                                       "loss_plus_distance"])
def test_fused_cascade_identical_to_host_splitfed(tiny_task, tiny_pcfg,
                                                  selection):
    data, module = tiny_task
    pcfg = dataclasses.replace(tiny_pcfg, lr=0.5)
    kw = dict(malicious={1}, attack=Attack(LABEL_FLIP), engine="batched",
              selection=selection)
    h_fused = run_splitfed(module, data, pcfg, **kw)
    h_host = run_splitfed(module, data, pcfg, _force_host_selection=True,
                          **kw)
    assert_records_identical(h_fused, h_host,
                             keys=("selected", "val_losses",
                                   "selected_honest", "test_acc"))


@pytest.mark.parametrize("selection", ["argmin", "trimmed",
                                       "loss_plus_distance"])
def test_sweep_selection_matches_per_seed(tiny_task, tiny_pcfg, selection):
    """The multi-seed sweep binds the same policy programs: each replica
    reproduces the corresponding single-seed fused run."""
    data, module = tiny_task
    hists = run_pigeon_sweep(module, data, tiny_pcfg, malicious={1},
                             attack=Attack(LABEL_FLIP), seeds=(0, 1),
                             selection=selection)
    for i, seed in enumerate((0, 1)):
        h_ref = run_pigeon(module, data,
                           dataclasses.replace(tiny_pcfg, seed=seed),
                           malicious={1}, attack=Attack(LABEL_FLIP),
                           engine="batched", selection=selection)
        for rr, rw in zip(h_ref.rounds, hists[i].rounds):
            assert rr["selected"] == rw["selected"]
            np.testing.assert_allclose(rr["val_losses"], rw["val_losses"],
                                       rtol=2e-5, atol=1e-6)


def test_unknown_selection_rejected(tiny_task, tiny_pcfg):
    data, module = tiny_task
    with pytest.raises(ValueError, match="selection policy"):
        run_pigeon(module, data, tiny_pcfg, malicious=set(), selection="warp")


# ---------------------------------------------------------------------------
# the stealth/replay recovery property (the robustness-matrix finding)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["stealth", "replay"])
def test_loss_plus_distance_recovers_stealth_replay(family):
    """PR 2's robustness matrix showed stealth/replay evade loss argmin
    (selection honesty ~0).  loss_plus_distance must flag their message
    anomalies and keep selection honest, at a trimmed-down version of the
    matrix scale (M=8, N=3, 3 malicious clients spread over the clusters)."""
    from repro.data import build_image_task
    m = 8
    data, cfg = build_image_task("mnist", m_clients=m, d_m=80, d_o=60,
                                 n_test=100, seed=0)
    module = from_cnn(cfg)
    pcfg = ProtocolConfig(M=m, N=3, T=3, E=2, B=8, lr=0.03, seed=0)
    attack = stealth(0.97) if family == "stealth" else Attack(REPLAY)
    tm = ThreatModel.build({i: attack for i in (0, 1, 2)})
    h = run_pigeon(module, data, pcfg, threat_model=tm, engine="batched",
                   selection="loss_plus_distance")
    honest = [r["selected_honest"] for r in h.rounds]
    assert sum(honest) / len(honest) >= 0.8, honest


# ---------------------------------------------------------------------------
# evaluate: batched predict-and-count reduction
# ---------------------------------------------------------------------------

def test_evaluate_matches_host_argmax(tiny_task):
    data, module = tiny_task
    gamma, phi = module.init(jax.random.PRNGKey(0))
    acc = evaluate(module, gamma, phi, data.x_test, data.y_test, batch=64)
    # reference: full logits transfer + host argmax (the old implementation)
    correct = total = 0
    for i in range(0, data.x_test.shape[0], 64):
        logits = np.asarray(module.predict(
            gamma, phi, jnp.asarray(data.x_test[i:i + 64])))
        correct += (logits.argmax(-1) == data.y_test[i:i + 64]).sum()
        total += data.y_test[i:i + 64].shape[0]
    assert acc == pytest.approx(correct / total, abs=1e-9)
