"""Quantized cut-layer exchange: kernel vs oracle, protocol equivalence and
the security property.

Three layers of contract:

  * kernel — ``kernels.quant_exchange`` matches the ``ref.py`` pure-jnp
    oracle bit-for-bit in interpret mode, the round-trip error is within the
    per-row quantization step, and the fused stats equal
    ``message_stats_reference`` of the dequantized message.
  * accounting — ``CommMeter`` byte totals follow ``message_bytes`` exactly
    (1 byte/element + 4 bytes/row vs 4 bytes/element), float counts (the
    Table I quantities) are format-independent, and the engines stay
    bit-identical under quantization.
  * security — selection honesty under the paper's three attacks is
    unchanged by the quantized wire (the ISSUE's headline property).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ACTIVATION, GRADIENT, LABEL_FLIP, Attack, CommConfig,
                        ProtocolConfig, message_bytes, resolve_quant,
                        run_pigeon, run_splitfed)
from repro.kernels import ops, ref
from repro.kernels.quant_exchange import (FP8_E4M3, INT8, QMAX,
                                          check_format, fp8_supported,
                                          quant_dequant, quant_dequant_stats)

FORMATS = [INT8,
           pytest.param(FP8_E4M3,
                        marks=pytest.mark.skipif(not fp8_supported(),
                                                 reason="no jnp.float8_e4m3fn"))]


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("n,d", [(64, 32), (256, 160),
                                 pytest.param(512, 33, marks=pytest.mark.slow)])
def test_quant_roundtrip_matches_reference(fmt, n, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d)) * 3.0
    deq, scales = quant_dequant(x, fmt, block_n=64, interpret=True)
    deq_ref, scales_ref = ref.quant_roundtrip_reference(x, fmt)
    # same codebook, same scales — up to one float32 ulp of non-associativity
    # between the interpret-mode and pure-jnp multiply orders
    np.testing.assert_allclose(np.asarray(deq), np.asarray(deq_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_ref),
                               rtol=1e-6)


@pytest.mark.parametrize("fmt", FORMATS)
def test_quant_roundtrip_error_bound(fmt):
    """Per-row symmetric quantization error: every element is within one
    quantization step of the original (int8: scale/2 from rounding; fp8:
    relative precision of a 3-bit mantissa near the row max)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 96)) * 5.0
    deq, scales = ops.quant_roundtrip(x, fmt, interpret=True)
    err = np.abs(np.asarray(deq) - np.asarray(x))
    step = np.asarray(scales)[:, None]
    if fmt == INT8:
        bound = 0.5 * step + 1e-7
    else:
        # e4m3: relative error <= 2^-4 of the magnitude, plus the subnormal
        # floor at scale * 2^-9
        bound = np.abs(np.asarray(x)) * 2.0 ** -4 + step * 2.0 ** -9 + 1e-7
    assert (err <= bound).all(), float(np.max(err - bound))
    # the row scale is exactly rowmax/qmax
    np.testing.assert_allclose(
        np.asarray(scales),
        np.max(np.abs(np.asarray(x)), axis=1) / QMAX[fmt], rtol=1e-6)


@pytest.mark.parametrize("fmt", FORMATS)
def test_quant_stats_fusion_matches_reference(fmt):
    """The fused two-phase kernel's stats equal message_stats_reference of
    its own dequantized output, and its deq/scales equal the plain kernel."""
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 48)) + 0.5
    deq, scales, stats = quant_dequant_stats(x, fmt, block_n=32,
                                             interpret=True)
    deq_ref, scales_ref = ref.quant_roundtrip_reference(x, fmt)
    np.testing.assert_allclose(np.asarray(deq), np.asarray(deq_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(scales_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(stats),
                               np.asarray(ref.message_stats_reference(deq_ref)),
                               rtol=1e-5, atol=1e-6)


def test_quant_roundtrip_is_idempotent():
    """QDQ(QDQ(x)) == QDQ(x): dequantized values are exact codebook points."""
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 32))
    once, _ = ops.quant_roundtrip(x, INT8, interpret=True)
    twice, _ = ops.quant_roundtrip(once, INT8, interpret=True)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=1e-6, atol=1e-7)


def test_quant_cut_exchange_straight_through_grad():
    """The launch-layer wire op: forward quantizes the uplink, backward
    quantizes the downlink cotangent (not a pass-through of it)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
    w = jax.random.normal(jax.random.PRNGKey(5), (16,))

    def loss(x):
        return jnp.sum(ops.quant_cut_exchange(x, INT8) * w)

    g = jax.grad(loss)(x)
    cot = jnp.broadcast_to(w, x.shape)
    g_ref, _ = ref.quant_roundtrip_reference(cot, INT8)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))
    # fmt=None is the exact f32 identity, both directions
    assert ops.quant_cut_exchange(x, None) is x
    np.testing.assert_array_equal(
        np.asarray(jax.grad(lambda x: jnp.sum(ops.quant_cut_exchange(x, None)
                                              * w))(x)),
        np.asarray(cot))


def test_quant_format_validation():
    with pytest.raises(ValueError):
        check_format("int4")
    with pytest.raises(ValueError):
        CommConfig(quant="int4")
    assert resolve_quant("fp8") == FP8_E4M3
    assert resolve_quant(None) is None
    assert CommConfig(quant="e4m3").quant == FP8_E4M3


# ---------------------------------------------------------------------------
# accounting + engine equivalence
# ---------------------------------------------------------------------------

def test_message_bytes_accounting():
    # f32: 4 bytes/element; quantized: 1 byte/element + one f32 scale per row
    assert message_bytes(None, 16, 256) == 16 * 256 * 4
    assert message_bytes(INT8, 16, 256) == 16 * 256 + 16 * 4
    ratio = message_bytes(None, 16, 256) / message_bytes(INT8, 16, 256)
    assert ratio == pytest.approx(4 * 256 / 260)


def _comm_totals(h):
    keys = ("activation_bytes", "gradient_bytes", "param_bytes",
            "validation_bytes", "activation_floats", "gradient_floats",
            "param_floats", "validation_floats", "client_passes")
    return {k: sum(r["comm"][k] for r in h.rounds) for k in keys}


def test_pigeon_quant_bytes_and_float_counts(tiny_task, tiny_pcfg):
    """int8 cuts exchange bytes by 4*d_c/(d_c+4) while the Table I float
    counts and the defense-critical param/validation traffic stay put."""
    data, module = tiny_task
    h32 = run_pigeon(module, data, tiny_pcfg)
    h8 = run_pigeon(module, data, tiny_pcfg, quant="int8")
    t32, t8 = _comm_totals(h32), _comm_totals(h8)
    for k in ("activation_floats", "gradient_floats", "param_floats",
              "validation_floats", "client_passes", "param_bytes",
              "validation_bytes"):
        assert t32[k] == t8[k], k
    d_c = 32                                    # MNIST_CNN fc_sizes=(32,)
    expect = 4 * d_c / (d_c + 4)
    assert t32["activation_bytes"] / t8["activation_bytes"] == pytest.approx(expect)
    assert t32["gradient_bytes"] / t8["gradient_bytes"] == pytest.approx(expect)


@pytest.mark.parametrize("placement", ["vmap",
                                       pytest.param("sharded",
                                                    marks=pytest.mark.slow)])
def test_pigeon_engine_equivalence_under_quant(tiny_task, tiny_pcfg, placement):
    """Sequential and batched engines agree on trajectory and report
    bit-identical CommMeter records under the quantized wire."""
    data, module = tiny_task
    h_seq = run_pigeon(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), quant="int8")
    h_bat = run_pigeon(module, data, tiny_pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP), engine="batched",
                       placement=placement, quant="int8")
    for rs, rb in zip(h_seq.rounds, h_bat.rounds):
        assert rs["selected"] == rb["selected"]
        assert rs["selected_honest"] == rb["selected_honest"]
        np.testing.assert_allclose(rs["val_losses"], rb["val_losses"],
                                   rtol=2e-5, atol=1e-6)
        assert rs["comm"] == rb["comm"]


@pytest.mark.parametrize("quant", [None, "int8"])
def test_splitfed_comm_identical_across_engines(tiny_task, tiny_pcfg, quant):
    """run_splitfed now meters communication; the analytic per-round record
    is bit-identical between the sequential and batched engines and is
    non-zero (the pre-fix behaviour was no ``comm`` record at all)."""
    data, module = tiny_task
    h_seq = run_splitfed(module, data, tiny_pcfg, quant=quant)
    h_bat = run_splitfed(module, data, tiny_pcfg, engine="batched",
                         quant=quant)
    for rs, rb in zip(h_seq.rounds, h_bat.rounds):
        assert rs["comm"] == rb["comm"]
        assert rs["comm"]["activation_bytes"] > 0
        assert rs["comm"]["param_bytes"] > 0


# ---------------------------------------------------------------------------
# the security property
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("attack", [Attack(LABEL_FLIP), Attack(ACTIVATION),
                                    Attack(GRADIENT)], ids=lambda a: a.kind)
def test_selection_honesty_unchanged_under_quant(tiny_task, attack):
    """The paper's three attacks: the selected-cluster sequence — hence the
    honesty of every selection — is identical with and without int8
    quantization of the cut-layer wire."""
    data, module = tiny_task
    pcfg = ProtocolConfig(M=4, N=1, T=3, E=2, B=16, lr=0.05, seed=0)
    h32 = run_pigeon(module, data, pcfg, malicious={1}, attack=attack,
                     engine="batched")
    h8 = run_pigeon(module, data, pcfg, malicious={1}, attack=attack,
                    engine="batched", quant="int8")
    assert [r["selected"] for r in h32.rounds] == \
           [r["selected"] for r in h8.rounds]
    assert [r["selected_honest"] for r in h32.rounds] == \
           [r["selected_honest"] for r in h8.rounds]
