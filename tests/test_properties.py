"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.attacks import (ACTIVATION, GRADIENT, LABEL_FLIP, Attack,
                                flip_labels, tamper_activation, tamper_gradient)
from repro.core.clustering import has_honest_cluster, make_clusters
from repro.launch.hlo_analysis import _type_bytes, _shape_dims
from repro.models.moe import MoEConfig, capacity


# ---------------------------------------------------------------------------
# pigeonhole clustering invariants (eq. (1) + the honest-cluster guarantee)
# ---------------------------------------------------------------------------

@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 10**9))
@settings(max_examples=100, deadline=None)
def test_clusters_partition_and_pigeonhole(r, size_per, seed):
    m = r * size_per
    rng = np.random.default_rng(seed)
    clusters = make_clusters(rng, m, r)
    # (i) disjoint, (ii) covering
    all_members = sorted(c for cl in clusters for c in cl)
    assert all_members == list(range(m))
    assert len(clusters) == r
    assert all(len(c) == size_per for c in clusters)
    # pigeonhole: any adversary set of size N = r-1 leaves an honest cluster
    n = r - 1
    malicious = set(rng.choice(m, size=min(n, m), replace=False).tolist())
    assert has_honest_cluster(clusters, malicious)


@given(st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_adversary_can_poison_at_most_n_clusters(r):
    """With N = r-1 malicious clients, at most N clusters are touched."""
    rng = np.random.default_rng(0)
    m = r * 3
    clusters = make_clusters(rng, m, r)
    malicious = set(range(r - 1))          # worst case: N distinct clients
    touched = sum(1 for cl in clusters if any(c in malicious for c in cl))
    assert touched <= r - 1


# ---------------------------------------------------------------------------
# attack transforms
# ---------------------------------------------------------------------------

@given(st.integers(2, 50), st.integers(1, 49), st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_label_flip_is_shift_and_stays_in_range(n_classes, shift, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.integers(0, n_classes, 32))
    a = Attack(LABEL_FLIP, label_shift=shift)
    y2 = flip_labels(a, y, n_classes)
    assert bool(jnp.all((y2 >= 0) & (y2 < n_classes)))
    assert bool(jnp.all(((y2 - y) % n_classes) == shift % n_classes))


@given(st.integers(1, 8), st.integers(2, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_activation_tamper_preserves_scale(b, d, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(0, 1, (b, d)) + 0.1)
    a = Attack(ACTIVATION)
    out = tamper_activation(a, x, jax.random.PRNGKey(seed % 1000))
    # norm-matched noise: by the triangle inequality the per-sample output
    # norm cannot exceed the input norm (0.1|a| + 0.9|a|)
    xi = np.linalg.norm(np.asarray(x), axis=1)
    oi = np.linalg.norm(np.asarray(out), axis=1)
    assert np.all(oi <= xi * (1 + 1e-4) + 1e-3)
    # and the attack actually changes the message (d >= 2: the noise
    # direction almost surely differs from the activation direction)
    assert float(jnp.abs(out - x).max()) > 0


@given(st.integers(1, 5), st.integers(1, 32))
@settings(max_examples=30, deadline=None)
def test_gradient_tamper_is_involution(b, d):
    g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (b, d)))
    a = Attack(GRADIENT)
    assert bool(jnp.all(tamper_gradient(a, tamper_gradient(a, g)) == g))
    assert bool(jnp.all(tamper_gradient(a, g) == -g))


# ---------------------------------------------------------------------------
# MoE capacity arithmetic
# ---------------------------------------------------------------------------

@given(st.integers(1, 4096), st.integers(1, 64).filter(lambda e: e <= 64),
       st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_moe_capacity_covers_perfect_balance(tokens, n_experts, top_k):
    top_k = min(top_k, n_experts)
    cfg = MoEConfig(d_model=8, d_expert=8, n_experts=n_experts, top_k=top_k,
                    capacity_factor=1.0)
    c = capacity(tokens, cfg)
    assert c * n_experts >= tokens * top_k       # perfectly balanced fits
    assert c % 8 == 0                            # TPU-aligned slots


# ---------------------------------------------------------------------------
# HLO type parsing
# ---------------------------------------------------------------------------

@given(st.sampled_from(["f32", "bf16", "s32", "pred", "f16"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
@settings(max_examples=50, deadline=None)
def test_hlo_type_bytes(dtype, dims):
    bytes_per = {"f32": 4, "bf16": 2, "s32": 4, "pred": 1, "f16": 2}[dtype]
    n = int(np.prod(dims)) if dims else 1
    s = f"{dtype}[{','.join(map(str, dims))}]"
    assert _type_bytes(s) == n * bytes_per
    assert _shape_dims(s) == dims


def test_hlo_tuple_type_bytes():
    s = "(f32[2,3]{1,0}, bf16[4]{0}, s32[])"
    assert _type_bytes(s) == 24 + 8 + 4


# ---------------------------------------------------------------------------
# checkpoint roundtrip over random pytrees
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip(seed, depth):
    import tempfile, os
    from repro.checkpoint import restore_pytree, save_checkpoint
    rng = np.random.default_rng(seed)

    def rand_tree(d):
        if d == 0:
            return jnp.asarray(rng.normal(0, 1, rng.integers(1, 5, size=2)))
        return {f"k{i}": rand_tree(d - 1) for i in range(2)}

    tree = rand_tree(depth)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt")
        save_checkpoint(path, tree, {"seed": seed})
        back = restore_pytree(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
