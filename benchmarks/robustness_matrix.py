"""Robustness matrix: protocol x threat-model x selection-policy grid over
the extended adversary and selection subsystems.

Every threat model in the catalogue — the paper's three attacks, the extended
families (backdoor, Byzantine scaling, gradient noise, replay, stealth,
param tampering), intermittent/ramp schedules and a mixed population — is run
against vanilla SL (no defence), Pigeon-SL (batched engine) under each
requested selection policy, and Pigeon-SL+ (argmin), recording the final
test accuracy, Pigeon-SL's selected-cluster honesty rate and tamper
detections.  The selection axis checks in the headline recovery: stealth and
replay adversaries evade pure loss argmin (honesty rate ~0, the PR 2
finding) but are flagged by ``loss_plus_distance``'s activation-message
anomaly scores.  Results land in ``experiments/robustness_matrix.json`` with
the full ThreatModel manifests for provenance.

    PYTHONPATH=src python -m benchmarks.run --only robustness [--full]
        [--selection argmin,loss_plus_distance]
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core import (ACTIVATION, Attack, BACKDOOR, GRADIENT, GRAD_NOISE,
                        GRAD_SCALE, LABEL_FLIP, PARAM_TAMPER, REPLAY,
                        ClientThreat, ProtocolConfig, ThreatModel, every_k,
                        from_cnn, ramp, run_pigeon, run_pigeon_plus,
                        run_vanilla_sl, stealth)
from repro.data import build_image_task

from .common import RoundTimer, csv_row, save_result

DEFAULT_SELECTIONS = ("argmin", "loss_plus_distance")
DEFAULT_QUANT_FORMATS = ("int8",)

#: the quant axis's threat rows: the paper's three attacks (label flipping,
#: activation tampering, gradient tampering) plus honest and the two
#: anomaly-score-sensitive families (replay/stealth) — the rows where a
#: quantization-induced selection flip would show first.
QUANT_ROWS = ("honest", "label_flip", "act_tamper", "grad_tamper", "replay",
              "stealth")


def _threat_catalogue(mal: Tuple[int, ...]) -> Dict[str, ThreatModel]:
    """The benchmark's rows.  ``mal`` is the malicious id pool (size 3 at
    reduced scale) — every row stays within the pigeonhole budget N."""
    a, b, c = mal
    return {
        "honest": ThreatModel.build({}),
        "label_flip": ThreatModel.build({i: Attack(LABEL_FLIP) for i in mal}),
        # the paper's other two attack families in their default forms:
        # norm-matched noise blend on the uplink, sign flip on the downlink
        "act_tamper": ThreatModel.build({i: Attack(ACTIVATION) for i in mal}),
        "grad_tamper": ThreatModel.build({i: Attack(GRADIENT) for i in mal}),
        "backdoor": ThreatModel.build(
            {i: Attack(BACKDOOR, target=7) for i in mal}),
        "grad_scale_x8": ThreatModel.build(
            {i: Attack(GRAD_SCALE, grad_scale=8.0) for i in mal}),
        "grad_noise": ThreatModel.build(
            {i: Attack(GRAD_NOISE, noise_std=2.0) for i in mal}),
        "replay": ThreatModel.build({i: Attack(REPLAY) for i in mal}),
        "stealth": ThreatModel.build({i: stealth(0.97) for i in mal}),
        "label_flip_every2": ThreatModel.build(
            {i: ClientThreat(Attack(LABEL_FLIP), every_k(2)) for i in mal}),
        "grad_scale_ramp": ThreatModel.build(
            {i: ClientThreat(Attack(GRAD_SCALE, grad_scale=8.0), ramp(4))
             for i in mal}),
        # mixed population: two label flippers + one Byzantine gradient scaler
        "mixed_2flip_1scale": ThreatModel.build({
            a: Attack(LABEL_FLIP),
            b: Attack(LABEL_FLIP),
            c: Attack(GRAD_SCALE, grad_scale=8.0),
        }),
        "param_tamper": ThreatModel.build(
            {i: Attack(PARAM_TAMPER) for i in mal}),
    }


def _pigeon_cell(h) -> Dict[str, float]:
    honest_sel = [r["selected_honest"] for r in h.rounds]
    return dict(
        final_acc=h.rounds[-1]["test_acc"],
        honest_rate=sum(honest_sel) / len(honest_sel),
        detections=sum(r["detections"] for r in h.rounds),
        rejected_rounds=sum(1 for r in h.rounds if not r.get("accepted", True)),
    )


def run(full: bool = False,
        selections: Sequence[str] = DEFAULT_SELECTIONS) -> None:
    if full:
        m, n, t, e, bsz, d_m, d_o, n_test, lr = 12, 3, 30, 20, 64, 2000, 1500, 4000, 1e-2
    else:
        m, n, t, e, bsz, d_m, d_o, n_test, lr = 8, 3, 5, 3, 16, 160, 100, 300, 0.03
    data, cfg = build_image_task("mnist", m_clients=m, d_m=d_m, d_o=d_o,
                                 n_test=n_test, seed=0)
    module = from_cnn(cfg)
    pcfg = ProtocolConfig(M=m, N=n, T=t, E=e, B=bsz, lr=lr, seed=0)
    catalogue = _threat_catalogue((0, 1, 2))
    selections = tuple(selections)
    if not selections:
        raise ValueError("robustness matrix needs at least one selection "
                         "policy on its policy axis")

    grid: Dict[str, Dict[str, object]] = {}
    for name, tm in catalogue.items():
        grid[name] = {}
        runs = 2 + len(selections)           # vanilla + pigeon+ + policy axis
        with RoundTimer() as timer:
            h_v = run_vanilla_sl(module, data, pcfg, threat_model=tm)
            pigeon = {}
            for sel in selections:
                h = run_pigeon(module, data, pcfg, threat_model=tm,
                               engine="batched", selection=sel)
                pigeon[sel] = _pigeon_cell(h)
            # throughput-matched variant: the fair accuracy comparison
            # (argmin selection — the paper's rule)
            h_pp = run_pigeon_plus(module, data, pcfg, threat_model=tm,
                                   engine="batched")
        grid[name]["vanilla"] = dict(final_acc=h_v.rounds[-1]["test_acc"])
        grid[name]["pigeon"] = pigeon
        grid[name]["pigeon_plus"] = _pigeon_cell(h_pp)
        csv_row(f"robustness_{name}", timer.us_per(runs * t),
                ";".join([f"pigeon_honest_rate[{sel}]="
                          f"{pigeon[sel]['honest_rate']:.2f}"
                          for sel in selections]
                         + [f"acc_pigeon+={grid[name]['pigeon_plus']['final_acc']:.3f}",
                            f"acc_vanilla={grid[name]['vanilla']['final_acc']:.3f}"]))

    save_result("robustness_matrix", dict(
        scale=dict(M=m, N=n, T=t, E=e, B=bsz, d_m=d_m, d_o=d_o,
                   n_test=n_test, lr=lr, full=full),
        selections=list(selections),
        threat_models={name: tm.describe() for name, tm in catalogue.items()},
        grid=grid,
    ))


# ---------------------------------------------------------------------------
# the --quant axis: selection honesty must survive the quantized wire
# ---------------------------------------------------------------------------

def _exchange_bytes(h) -> int:
    """Total cut-layer wire bytes (activations + cut gradients) of a run."""
    return sum(r["comm"]["activation_bytes"] + r["comm"]["gradient_bytes"]
               for r in h.rounds)


def _quant_cell(h) -> Dict[str, object]:
    honest_sel = [r["selected_honest"] for r in h.rounds]
    return dict(
        final_acc=h.rounds[-1]["test_acc"],
        honest_rate=sum(honest_sel) / len(honest_sel),
        selected=[r["selected"] for r in h.rounds],
        detections=sum(r["detections"] for r in h.rounds),
        exchange_bytes=_exchange_bytes(h),
        exchange_floats=sum(r["comm"]["activation_floats"]
                            + r["comm"]["gradient_floats"] for r in h.rounds),
    )


def run_quant(full: bool = False,
              selections: Sequence[str] = DEFAULT_SELECTIONS,
              formats: Sequence[Optional[str]] = DEFAULT_QUANT_FORMATS) -> None:
    """Pigeon-SL under the quantized cut-layer wire vs the f32 baseline, for
    each threat row in :data:`QUANT_ROWS` and each selection policy: the
    security property (per "Security Analysis of SplitFed Learning":
    robustness claims must be re-validated under any message transform) is
    that the selected-cluster sequence — hence selection honesty — is
    unchanged, while the measured exchange bytes drop by ~4x.

    The quant grid widens the benchmark CNN's cut layer to 256 units: the
    reduced-scale model's 32-wide cut is an artifact of the 1-core container
    (the paper's models cut at hundreds-to-thousands of units), and the byte
    win ``4*d_c/(d_c + 4)`` only reflects deployment reality once d_c is in
    that regime."""
    if full:
        m, n, t, e, bsz, d_m, d_o, n_test, lr = 12, 3, 30, 20, 64, 2000, 1500, 4000, 1e-2
    else:
        m, n, t, e, bsz, d_m, d_o, n_test, lr = 8, 3, 5, 3, 16, 160, 100, 300, 0.03
    data, cfg = build_image_task("mnist", m_clients=m, d_m=d_m, d_o=d_o,
                                 n_test=n_test, seed=0)
    cfg = dataclasses.replace(cfg, name=cfg.name + "_wide",
                              fc_sizes=(256,) + cfg.fc_sizes[1:])
    module = from_cnn(cfg)
    pcfg = ProtocolConfig(M=m, N=n, T=t, E=e, B=bsz, lr=lr, seed=0)
    catalogue = {name: tm for name, tm in _threat_catalogue((0, 1, 2)).items()
                 if name in QUANT_ROWS}
    selections = tuple(selections)
    formats = tuple(formats)
    if not selections or not formats:
        raise ValueError("the quant axis needs at least one selection policy "
                         "and one quant format")

    grid: Dict[str, Dict[str, object]] = {}
    all_match = True
    worst_ratio = float("inf")
    for name, tm in catalogue.items():
        grid[name] = {}
        runs = len(selections) * (1 + len(formats))
        with RoundTimer() as timer:
            for sel in selections:
                base = run_pigeon(module, data, pcfg, threat_model=tm,
                                  engine="batched", selection=sel)
                cells: Dict[str, object] = {"f32": _quant_cell(base)}
                for fmt in formats:
                    hq = run_pigeon(module, data, pcfg, threat_model=tm,
                                    engine="batched", selection=sel, quant=fmt)
                    cell = _quant_cell(hq)
                    cell["selection_match"] = (cell["selected"]
                                               == cells["f32"]["selected"])
                    cell["bytes_ratio_vs_f32"] = (
                        cells["f32"]["exchange_bytes"] / cell["exchange_bytes"])
                    all_match = all_match and cell["selection_match"]
                    worst_ratio = min(worst_ratio, cell["bytes_ratio_vs_f32"])
                    cells[fmt] = cell
                grid[name][sel] = cells
        first = grid[name][selections[0]][formats[0]]
        match = "/".join(str(int(grid[name][s][f]["selection_match"]))
                         for s in selections for f in formats)
        csv_row(f"robustness_quant_{name}", timer.us_per(runs * t),
                f"match={match};bytes_ratio={first['bytes_ratio_vs_f32']:.2f}")

    save_result("robustness_matrix_quant", dict(
        scale=dict(M=m, N=n, T=t, E=e, B=bsz, d_m=d_m, d_o=d_o,
                   n_test=n_test, lr=lr, full=full, d_c=cfg.d_cut),
        selections=list(selections),
        formats=list(formats),
        rows=list(catalogue),
        threat_models={name: tm.describe() for name, tm in catalogue.items()},
        all_selection_match=all_match,
        worst_bytes_ratio=worst_ratio,
        grid=grid,
    ))
