"""Fig. 3: MNIST test accuracy under the three attacks, for vanilla SL,
SplitFed (clustered), Pigeon-SL and Pigeon-SL+ (N=3 in the paper)."""
from __future__ import annotations

import dataclasses

from repro.core import (ACTIVATION, GRADIENT, LABEL_FLIP, Attack,
                        from_cnn, run_pigeon, run_splitfed, run_vanilla_sl)
from repro.data import build_image_task

from .common import (RoundTimer, csv_row, mnist_scale, moving_average,
                     pcfg_from, save_result)

ATTACKS = [("label_flip", Attack(LABEL_FLIP)),
           ("activation", Attack(ACTIVATION)),
           ("gradient", Attack(GRADIENT))]


def run(full: bool = False, seed: int = 0):
    scale = mnist_scale(full)
    data, cnn_cfg = build_image_task("mnist", m_clients=scale.m, d_m=scale.d_m,
                                     d_o=scale.d_o, n_test=scale.n_test,
                                     seed=seed)
    module = from_cnn(cnn_cfg)
    pcfg = pcfg_from(scale, seed)
    malicious = set(range(scale.n))
    out = {"scale": dataclasses.asdict(scale), "curves": {}}

    for attack_name, attack in ATTACKS:
        curves = {}
        with RoundTimer() as t:
            h = run_vanilla_sl(module, data, pcfg, malicious, attack)
        curves["vanilla_sl"] = h.series("test_acc")
        us = t.us_per(pcfg.T)
        with RoundTimer() as t:
            h = run_splitfed(module, data,
                             dataclasses.replace(pcfg, lr=scale.lr_sfl),
                             malicious, attack)
        curves["splitfed"] = h.series("test_acc")
        with RoundTimer() as t:
            h = run_pigeon(module, data, pcfg, malicious, attack, plus=False)
        curves["pigeon_sl"] = h.series("test_acc")
        with RoundTimer() as t:
            h = run_pigeon(module, data, pcfg, malicious, attack, plus=True)
        curves["pigeon_sl_plus"] = h.series("test_acc")
        out["curves"][attack_name] = curves

        final = {k: v[-1] for k, v in curves.items()}
        csv_row(f"fig3_mnist_{attack_name}", us,
                f"pigeon+={final['pigeon_sl_plus']:.3f};"
                f"pigeon={final['pigeon_sl']:.3f};"
                f"vanilla={final['vanilla_sl']:.3f};"
                f"sfl={final['splitfed']:.3f}")
    save_result("fig3_mnist_attacks", out)
    return out


if __name__ == "__main__":
    run()
