"""Round-block fusion: K scanned rounds per host sync vs per-round dispatch.

The per-round batched engine already fuses everything *inside* one round —
training, validation and the acceptance cascade are one compiled program
with one stacked host fetch — but each round still pays a Python-side
dispatch, a device->host sync for its (2R+3,) fetch and the host bookkeeping
between rounds.  Round-block execution (``run_pigeon(block=K)``) scans K
rounds inside one ``lax.scan`` program with the theta carry donated, so a
block pays ONE dispatch and ONE stacked (K, 2R+3) fetch for K rounds.

The measurement regime is *small per-round compute*: a one-hidden-layer
split MLP over 8x8 synthetic images, E=1, B=4 — the corner edge deployments
with many cheap rounds live in, where per-round wall time is dominated by
dispatch + fetch + assembly overhead rather than FLOPs.  (With the paper's
CNNs at full batch sizes the device program dominates and fusion is
throughput-neutral — see ``pipeline_overlap`` for that regime's knob.)

Grid: R ∈ {2, 3} x block ∈ {1, 2, 4, 8}, written to
``experiments/round_fusion.json``.  Every measured cell is checked
bit-identical to its block=1 baseline — same selected-cluster sequence,
same History floats, same CommMeter counts — so the speedup column is a
pure execution-schedule measurement, not a numerics change.
"""
from __future__ import annotations

import dataclasses
import gc

import jax
import jax.numpy as jnp

from repro.core import ProtocolConfig, run_pigeon
from repro.core.protocol import ClientData
from repro.core.split import SplitModule, _xent
from repro.data import synthetic

from .common import RoundTimer, csv_row, save_result

BLOCKS = (1, 2, 4, 8)
IMG, HIDDEN, CLASSES = 8, 16, 10


def tiny_split_mlp(d_in: int = IMG * IMG, hidden: int = HIDDEN,
                   n_classes: int = CLASSES) -> SplitModule:
    """One matmul per half: the cheapest SplitModule that still exercises
    the full protocol structure (client chain, validation, cascade)."""

    def init(key):
        k1, k2 = jax.random.split(key)
        gamma = {"w": jax.random.normal(k1, (d_in, hidden)) * 0.1}
        phi = {"v": jax.random.normal(k2, (hidden, n_classes)) * 0.1,
               "b": jnp.zeros(n_classes)}
        return gamma, phi

    def client_forward(gamma, x):
        return jnp.maximum(x.reshape(x.shape[0], -1) @ gamma["w"], 0.0)

    return SplitModule(
        init=init, client_forward=client_forward,
        ap_loss=lambda phi, a, y: _xent(a @ phi["v"] + phi["b"], y),
        predict=lambda g, p, x: client_forward(g, x) @ p["v"] + p["b"],
        n_classes=n_classes)


def _assert_bit_identical(h_ref, h_blk, cell: str) -> None:
    assert len(h_ref.rounds) == len(h_blk.rounds), cell
    for ra, rb in zip(h_ref.rounds, h_blk.rounds):
        assert ra.keys() == rb.keys(), (cell, set(ra) ^ set(rb))
        for k in ra:
            assert ra[k] == rb[k], (cell, ra.get("round"), k)


def run(full: bool = False, seed: int = 0):
    grid = [(4, 1), (9, 2)]                  # (M, N) -> R = N+1 in {2, 3}
    timed_rounds = 64 if not full else 256
    repeats = 7
    d_m = 64

    results = {}
    for m, n in grid:
        arrs = synthetic.make_classification_data(seed, CLASSES, IMG, 1, m,
                                                  d_m, 16, 32)
        x, y, x0, y0, xt, yt = arrs
        data = ClientData(x=x, y=y, x0=x0, y0=y0, x_test=xt, y_test=yt)
        module = tiny_split_mlp()
        pcfg = ProtocolConfig(M=m, N=n, T=timed_rounds, E=1, B=4, lr=0.03,
                              seed=seed, eval_every=10 * timed_rounds)
        kw = dict(malicious=set(), engine="batched", placement="vmap")
        for block in BLOCKS:                 # compile every cell up front
            warm = dataclasses.replace(pcfg, T=2 * block)
            run_pigeon(module, data, warm, block=block, **kw)
        # Interleave the repeats across blocks so scheduler drift on the
        # shared-core container hits every cell, then take per-cell minima;
        # GC off while timing (a collection mid-run swamps ms-scale rounds).
        best = {b: float("inf") for b in BLOCKS}
        hists = {}
        gc.collect()
        gc.disable()
        try:
            for _ in range(repeats):
                for block in BLOCKS:
                    with RoundTimer() as timer:
                        hists[block] = run_pigeon(module, data, pcfg,
                                                  block=block, **kw)
                    best[block] = min(best[block], timer.us_per(pcfg.T))
        finally:
            gc.enable()
        rows = {}
        for block in BLOCKS:
            if block > 1:
                _assert_bit_identical(hists[1], hists[block],
                                      f"R{n + 1}_block{block}")
            rows[f"block{block}"] = dict(
                us_per_round=best[block],
                speedup=best[1] / best[block] if block > 1 else 1.0,
                selected=[r["selected"] for r in hists[block].rounds])
            csv_row(f"round_fusion_R{n + 1}_block{block}", best[block],
                    f"speedup={rows[f'block{block}']['speedup']:.2f}x")
        results[f"R{n + 1}"] = rows

    out = {"params": dict(grid=[list(g) for g in grid], blocks=list(BLOCKS),
                          T=timed_rounds, E=1, B=4, d_m=d_m, img=IMG,
                          hidden=HIDDEN, repeats=repeats),
           "rows": results}
    save_result("round_fusion", out)
    return out


if __name__ == "__main__":
    run()
