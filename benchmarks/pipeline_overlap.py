"""Pipeline overlap: synchronous vs double-buffered host-side round assembly.

Each Pigeon-SL round pays a host-side cost before the device can start —
sampling every client's (E, B) mini-batches into one stacked array, deriving
the per-client key grid, building the round's AttackVec — and a device cost
for the compiled round program itself.  Cluster selection is the only true
sync point, so the ``RoundFeeder`` (``repro/data/pipeline.py``) can assemble
round t+1 on a background thread while the device executes round t.

This benchmark times full ``run_pigeon`` protocol rounds (batched engine)
with ``prefetch=0`` (synchronous) vs ``prefetch=1`` (double-buffered) across
R ∈ {2, 4, 8}, writing ``experiments/pipeline_overlap.json``.  The two
trajectories are bit-identical (CI-tested), so the ratio is a pure
execution-overlap measurement.  The win is bounded by the smaller of the two
phases: it grows with the host share of the round (big B, shallow E) and
saturates near 1x when device compute dominates.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import ProtocolConfig, from_cnn, run_pigeon
from repro.data import build_image_task

from .common import csv_row, save_result


def run(full: bool = False, seed: int = 0):
    # Host-assembly-heavy regime: many clients, one wide mini-batch per turn
    # (E=1, large B) keeps the per-round gather/transfer volume high relative
    # to device compute — the corner the feeder is built for.
    m = 16
    d_m = 600 if not full else 2000
    data, cnn_cfg = build_image_task("mnist", m_clients=m, d_m=d_m, d_o=64,
                                     n_test=32, seed=seed)
    module = from_cnn(cnn_cfg)
    timed_rounds = 8 if not full else 20
    repeats = 3

    results = {}
    for r in (2, 4, 8):
        pcfg = ProtocolConfig(M=m, N=r - 1, T=timed_rounds, E=1, B=128,
                              lr=0.03, seed=seed, eval_every=10 * timed_rounds)
        ms = {}
        for prefetch in (0, 1):
            warm = dataclasses.replace(pcfg, T=2)
            run_pigeon(module, data, warm, malicious=set(), engine="batched",
                       prefetch=prefetch)
            best = float("inf")
            for _ in range(repeats):        # best-of-N vs scheduler noise
                t0 = time.perf_counter()
                run_pigeon(module, data, pcfg, malicious=set(),
                           engine="batched", prefetch=prefetch)
                best = min(best, (time.perf_counter() - t0) / pcfg.T * 1e3)
            ms[prefetch] = best
        overlap_win = ms[0] / ms[1]
        results[f"R{r}"] = dict(sync_ms=ms[0], prefetch_ms=ms[1],
                                overlap_win=overlap_win)
        csv_row(f"pipeline_overlap_R{r}", ms[1] * 1e3,
                f"sync_ms={ms[0]:.1f};prefetch_ms={ms[1]:.1f};"
                f"win={overlap_win:.2f}x")

    out = {"params": dict(M=m, d_m=d_m, E=1, B=128, rounds=timed_rounds,
                          repeats=repeats),
           "rows": results}
    save_result("pipeline_overlap", out)
    return out


if __name__ == "__main__":
    run()
