"""Shared benchmark plumbing.

Every benchmark runs at REDUCED scale by default (this container is one CPU
core); ``--full`` switches to the paper's Table II parameters.  Results are
written to experiments/ as JSON and summarised on stdout as
``name,us_per_call,derived`` CSV rows (us_per_call = wall-microseconds per
global round; derived = the benchmark's headline metric)."""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core import Attack, ProtocolConfig
from repro.telemetry import Stopwatch, provenance

EXP_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "experiments")


@dataclasses.dataclass
class BenchScale:
    m: int
    n: int
    t: int
    e: int
    b: int
    d_m: int
    d_o: int
    n_test: int
    lr: float
    lr_sfl: float


def mnist_scale(full: bool) -> BenchScale:
    if full:   # Table II
        return BenchScale(m=12, n=3, t=60, e=79, b=64, d_m=5000, d_o=3000,
                          n_test=7000, lr=1e-3, lr_sfl=1e-2)
    return BenchScale(m=8, n=3, t=10, e=6, b=32, d_m=400, d_o=200,
                      n_test=1000, lr=0.03, lr_sfl=0.3)


def cifar_scale(full: bool) -> BenchScale:
    if full:   # Table II
        return BenchScale(m=20, n=4, t=60, e=40, b=64, d_m=2500, d_o=3000,
                          n_test=7000, lr=2e-4, lr_sfl=2e-3)
    # the 128-filter CIFAR CNN is ~40x the MNIST model per update on this
    # 1-core container: keep the reduced grid small
    return BenchScale(m=5, n=4, t=5, e=4, b=16, d_m=150, d_o=80,
                      n_test=300, lr=0.05, lr_sfl=0.5)


def pcfg_from(scale: BenchScale, seed: int = 0, n: Optional[int] = None) -> ProtocolConfig:
    return ProtocolConfig(M=scale.m, N=scale.n if n is None else n, T=scale.t,
                          E=scale.e, B=scale.b, lr=scale.lr, seed=seed,
                          eval_every=1)


def moving_average(xs: List[float], w: int) -> List[float]:
    out = []
    for i in range(len(xs)):
        lo = max(0, i - w + 1)
        out.append(float(np.mean(xs[lo : i + 1])))
    return out


def save_result(name: str, payload: Dict[str, Any]) -> str:
    # every result JSON carries a provenance stamp (jax/jaxlib versions,
    # backend, device kind, git sha, timestamp) so numbers in experiments/
    # stay attributable after the environment moves on
    payload.setdefault("provenance", provenance())
    os.makedirs(EXP_DIR, exist_ok=True)
    path = os.path.join(EXP_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def csv_row(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.0f},{derived}", flush=True)


def throughput_fields(elapsed_s: float, rounds: int, jobs: int = 1,
                      dispatches: int = 0) -> Dict[str, float]:
    """Comparable throughput fields for ``experiments/*.json`` result
    records: rounds/sec and jobs/sec over the timed window, plus the mean
    device dispatches per round (1.0 = per-round execution; 1/K under
    round-block fusion; 1/(J*K) per job-round under the job pool) — so the
    perf trajectory stays comparable across PRs."""
    e = max(elapsed_s, 1e-12)
    return {"rounds_per_sec": rounds / e,
            "jobs_per_sec": jobs / e,
            "dispatches_per_round": dispatches / max(rounds, 1)}


class RoundTimer(Stopwatch):
    """A :class:`repro.telemetry.Stopwatch` (monotonic ``perf_counter`` —
    wall-clock ``time.time()`` can step under NTP) reporting per-round us."""

    def us_per(self, rounds: int) -> float:
        return self.elapsed / max(rounds, 1) * 1e6
