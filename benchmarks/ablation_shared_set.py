"""Beyond-paper ablation: selection reliability vs shared-set size D_o and
client heterogeneity (non-IID Dirichlet shards).

The paper fixes D_o = 3000 and assumes i.i.d. clients. Two questions it
leaves open:
  1. How small can D_o be before the argmin selection starts picking
     malicious clusters? (D_o is pure communication overhead — Table I's
     2R*D_o*d_c term — so smaller is cheaper.)
  2. Does the honest-cluster guarantee survive non-IID clients, where an
     honest-but-skewed cluster can have a high validation loss?
"""
from __future__ import annotations

import dataclasses

from repro.core import Attack, LABEL_FLIP, ProtocolConfig, from_cnn, run_pigeon
from repro.data import build_image_task, dirichlet_relabel

from .common import RoundTimer, csv_row, save_result


def run(full: bool = False, seed: int = 0):
    t_rounds = 8 if full else 4
    out = {"do_sweep": {}, "noniid_sweep": {}}

    us = 0.0
    for d_o in ([25, 100, 400, 1600] if full else [10, 50, 200]):
        data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=300,
                                         d_o=d_o, n_test=500, seed=seed)
        module = from_cnn(cnn_cfg)
        pcfg = ProtocolConfig(M=4, N=1, T=t_rounds, E=5, B=32, lr=0.05,
                              seed=seed)
        with RoundTimer() as t:
            h = run_pigeon(module, data, pcfg, malicious={1},
                           attack=Attack(LABEL_FLIP))
        us = t.us_per(pcfg.T)
        honest_rate = sum(r["selected_honest"] for r in h.rounds) / len(h.rounds)
        out["do_sweep"][d_o] = dict(
            honest_selection_rate=honest_rate,
            final_acc=h.rounds[-1]["test_acc"])

    for alpha in ([0.1, 0.5, 100.0] if full else [0.2, 100.0]):
        data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=300,
                                         d_o=150, n_test=500, seed=seed)
        data = dirichlet_relabel(data, alpha, seed=seed)
        module = from_cnn(cnn_cfg)
        pcfg = ProtocolConfig(M=4, N=1, T=t_rounds, E=5, B=32, lr=0.05,
                              seed=seed)
        h = run_pigeon(module, data, pcfg, malicious={1},
                       attack=Attack(LABEL_FLIP))
        honest_rate = sum(r["selected_honest"] for r in h.rounds) / len(h.rounds)
        out["noniid_sweep"][alpha] = dict(
            honest_selection_rate=honest_rate,
            final_acc=h.rounds[-1]["test_acc"])

    derived = ";".join(
        [f"Do{k}_honest={v['honest_selection_rate']:.2f}"
         for k, v in out["do_sweep"].items()]
        + [f"a{k}_acc={v['final_acc']:.2f}" for k, v in out["noniid_sweep"].items()])
    csv_row("ablation_shared_set", us, derived)
    save_result("ablation_shared_set", out)
    return out


if __name__ == "__main__":
    run()
