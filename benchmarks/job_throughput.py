"""Job-pool megabatching: J pooled jobs vs the serial per-job loop.

The production regime the ROADMAP targets is many concurrent *small* jobs.
Run serially, each job pays its own per-block dispatch, (K, 2R+3) host fetch
and host bookkeeping; the job pool (``repro.core.jobs.run_job_pool``) stacks
J compatible jobs onto a leading lane of ONE shared ``accept_block`` program
— one dispatch and one stacked (J, K, 2R+3) fetch per pool block — so the
overhead amortises J-fold on top of round-block fusion's K-fold.

Same measurement regime as ``round_fusion``: the tiny one-matmul-per-half
split MLP at E=1, B=4, where per-round wall time is dispatch/fetch/assembly
bound rather than FLOPs bound.  Every pooled job's History is asserted
bit-identical to its solo run before any timing is trusted, so the jobs/sec
column is a pure execution-schedule measurement.

Writes ``experiments/job_throughput.json`` with the throughput fields
(jobs/sec, rounds/sec, dispatches/round) from ``benchmarks.common``.
"""
from __future__ import annotations

import dataclasses
import gc

from repro.core import ProtocolConfig, run_pigeon
from repro.core.jobs import JobSpec, run_job_pool
from repro.core.protocol import ClientData
from repro.data import synthetic

from .common import RoundTimer, csv_row, save_result, throughput_fields
from .round_fusion import CLASSES, IMG, _assert_bit_identical, tiny_split_mlp


def _make_specs(module, data, n_jobs: int, t: int, m: int, n: int,
                seed0: int):
    specs = []
    for s in range(n_jobs):
        pcfg = ProtocolConfig(M=m, N=n, T=t, E=1, B=4, lr=0.03,
                              seed=seed0 + s, eval_every=10 * t)
        specs.append(JobSpec(name=f"job{s}", module=module, data=data,
                             pcfg=pcfg))
    return specs


def run(full: bool = False, seed: int = 0):
    m, n = 4, 1
    n_jobs = 12 if full else 8
    block = 2
    timed_rounds = 128 if full else 64
    repeats = 5
    d_m = 64

    arrs = synthetic.make_classification_data(seed, CLASSES, IMG, 1, m,
                                              d_m, 16, 32)
    x, y, x0, y0, xt, yt = arrs
    data = ClientData(x=x, y=y, x0=x0, y0=y0, x_test=xt, y_test=yt)
    module = tiny_split_mlp()
    specs = _make_specs(module, data, n_jobs, timed_rounds, m, n, seed)
    solo_kw = dict(engine="batched", placement="vmap", block=block)

    # correctness first: every pooled job's History == its solo run
    pooled = run_job_pool(specs, block=block)
    solos = {}
    for s in specs:
        solos[s.name] = run_pigeon(s.module, s.data, s.pcfg, **solo_kw)
        _assert_bit_identical(solos[s.name], pooled[s.name],
                              f"pool_vs_solo_{s.name}")

    # compile warmup for both paths at the timed shapes (T=2*block keeps the
    # warm run to two blocks while hitting every (J, K) signature)
    warm_specs = [dataclasses.replace(s, pcfg=dataclasses.replace(
        s.pcfg, T=2 * block)) for s in specs]
    run_job_pool(warm_specs, block=block)
    for s in warm_specs:
        run_pigeon(s.module, s.data, s.pcfg, **solo_kw)

    best_serial = float("inf")
    best_pool = float("inf")
    gc.collect()
    gc.disable()
    try:
        for _ in range(repeats):
            with RoundTimer() as timer:
                for s in specs:
                    run_pigeon(s.module, s.data, s.pcfg, **solo_kw)
            best_serial = min(best_serial, timer.elapsed)
            with RoundTimer() as timer:
                run_job_pool(specs, block=block)
            best_pool = min(best_pool, timer.elapsed)
    finally:
        gc.enable()

    total_rounds = n_jobs * timed_rounds
    blocks_per_job = -(-timed_rounds // block)          # ceil
    serial = dict(
        wall_s=best_serial,
        **throughput_fields(best_serial, total_rounds, n_jobs,
                            dispatches=n_jobs * blocks_per_job))
    pool = dict(
        wall_s=best_pool,
        **throughput_fields(best_pool, total_rounds, n_jobs,
                            dispatches=blocks_per_job))
    speedup = serial["wall_s"] / pool["wall_s"]

    csv_row("job_throughput_serial", best_serial / total_rounds * 1e6,
            f"jobs_per_sec={serial['jobs_per_sec']:.2f}")
    csv_row("job_throughput_pool", best_pool / total_rounds * 1e6,
            f"jobs_per_sec={pool['jobs_per_sec']:.2f} "
            f"speedup={speedup:.2f}x")

    out = {"params": dict(n_jobs=n_jobs, block=block, T=timed_rounds,
                          M=m, N=n, E=1, B=4, d_m=d_m, img=IMG,
                          repeats=repeats, placement="vmap"),
           "bit_identical": True,
           "rows": {"serial": serial, "pool": pool},
           "speedup": speedup}
    save_result("job_throughput", out)
    return out


if __name__ == "__main__":
    run()
