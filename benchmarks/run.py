"""Benchmark entrypoint: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall-us per
global protocol round; derived = headline metric) and writes full curves to
experiments/*.json.
"""
import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale Table II parameters (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="table1|fig3|fig4|fig5|ablation|roofline|robustness|"
                         "robustness_quant|pipeline|placements|fusion|pool")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable JAX's persistent compilation cache in DIR "
                         "(default: $REPRO_COMPILE_CACHE if set); repeated "
                         "grid cells and re-runs then load compiled round "
                         "programs from disk instead of re-compiling")
    ap.add_argument("--selection", default=None,
                    help="comma-separated selection policies for the "
                         "robustness matrix's policy axis (default: "
                         "argmin,loss_plus_distance)")
    ap.add_argument("--quant", default=None,
                    help="comma-separated cut-layer wire formats for the "
                         "robustness_quant matrix's format axis "
                         "(default: int8; e.g. int8,fp8_e4m3)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a JSONL telemetry trace (round spans + "
                         "per-round metrics + provenance) of the table1 "
                         "accounting runs to PATH")
    args = ap.parse_args()

    from repro.core import enable_compile_cache
    enable_compile_cache(args.compile_cache)   # no-op when dir/env unset

    telemetry = None
    if args.trace:
        if args.only not in (None, "table1"):
            ap.error("--trace only applies to the table1 accounting runs; "
                     f"it has no effect on --only {args.only}")
        from repro.telemetry import Telemetry
        telemetry = Telemetry(jsonl=args.trace, jit_stats=True)

    selections = None
    if args.selection:
        if args.only not in (None, "robustness", "robustness_quant"):
            ap.error("--selection only applies to the robustness matrices; "
                     f"it has no effect on --only {args.only}")
        from repro.selection import resolve_policy
        selections = tuple(s.strip() for s in args.selection.split(",") if s.strip())
        if not selections:
            ap.error(f"--selection {args.selection!r} parses to no policy names")
        for s in selections:
            resolve_policy(s)        # fail fast on typos, like --only

    formats = None
    if args.quant:
        if args.only not in (None, "robustness_quant"):
            ap.error("--quant only applies to the robustness_quant matrix; "
                     f"it has no effect on --only {args.only}")
        from repro.core import resolve_quant
        formats = tuple(q.strip() for q in args.quant.split(",") if q.strip())
        if not formats:
            ap.error(f"--quant {args.quant!r} parses to no format names")
        formats = tuple(resolve_quant(q) for q in formats)  # fail fast

    from . import (ablation_shared_set, fig3_mnist_attacks, fig4_cifar_attacks,
                   fig5_fig6_vary_n, job_throughput, pipeline_overlap,
                   placement_grid, robustness_matrix, roofline_report,
                   round_fusion, table1_overhead)

    benches = {
        "table1": lambda: table1_overhead.run(args.full, telemetry=telemetry),
        "fig3": lambda: fig3_mnist_attacks.run(args.full),
        "fig4": lambda: fig4_cifar_attacks.run(args.full),
        "fig5": lambda: fig5_fig6_vary_n.run(args.full),
        "ablation": lambda: ablation_shared_set.run(args.full),
        "roofline": lambda: roofline_report.run(markdown=False),
        "robustness": lambda: robustness_matrix.run(
            args.full, selections if selections is not None
            else robustness_matrix.DEFAULT_SELECTIONS),
        "robustness_quant": lambda: robustness_matrix.run_quant(
            args.full,
            selections if selections is not None
            else robustness_matrix.DEFAULT_SELECTIONS,
            formats if formats is not None
            else robustness_matrix.DEFAULT_QUANT_FORMATS),
        "pipeline": lambda: pipeline_overlap.run(args.full),
        "placements": lambda: placement_grid.run(args.full),
        "fusion": lambda: round_fusion.run(args.full),
        "pool": lambda: job_throughput.run(args.full),
    }
    if args.only and args.only not in benches:
        # an unknown name used to silently skip every benchmark and exit 0
        ap.error(f"--only {args.only!r} matches no benchmark; "
                 f"choose from {'|'.join(benches)}")
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
