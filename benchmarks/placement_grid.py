"""Placement grid: every protocol driver x every device placement.

PR 3 gave ``run_pigeon`` a placement-aware RoundRunner; this PR extends the
same bindings to ``run_splitfed`` (FedAvg-within-cluster as the RoundSpec
``combine`` hook) and ``run_pigeon_sweep`` (S x R replicas over a 2-D
``(seed, pod)`` mesh).  This benchmark times one full protocol run per
(driver, placement) cell — pigeon / splitfed under vmap vs sharded (plus the
prefetch pipeline), and the multi-seed sweep under vmap vs the 2-D sharded
placement — and writes ``experiments/placement_grid.json``.

On the CPU container the sharded cells collapse to a 1-device mesh unless
run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, so the
interesting single-host readout is the *overhead* of the shard_map plumbing
relative to vmap; on a real pod mesh the same cells scale out.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import (ProtocolConfig, from_cnn, run_pigeon,
                        run_pigeon_sweep, run_splitfed)
from repro.data import build_image_task

from .common import csv_row, save_result


def _time_best(fn, t_rounds: int, repeats: int) -> float:
    """Best-of-N wall-ms per protocol round (vs scheduler noise)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, (time.perf_counter() - t0) / t_rounds * 1e3)
    return best


def run(full: bool = False, seed: int = 0):
    m = 8
    d_m = 400 if not full else 2000
    data, cnn_cfg = build_image_task("mnist", m_clients=m, d_m=d_m, d_o=64,
                                     n_test=32, seed=seed)
    module = from_cnn(cnn_cfg)
    t_rounds = 6 if not full else 20
    repeats = 3
    pcfg = ProtocolConfig(M=m, N=3, T=t_rounds, E=2, B=32, lr=0.03, seed=seed,
                          eval_every=10 * t_rounds)
    warm = dataclasses.replace(pcfg, T=1)
    seeds = (0, 1)

    cells = {}
    for name, runner in (("pigeon", run_pigeon), ("splitfed", run_splitfed)):
        for placement, prefetch in (("vmap", 0), ("sharded", 0), ("vmap", 1)):
            cell = f"{name}/{placement}" + ("+prefetch" if prefetch else "")
            kw = dict(malicious=set(), engine="batched",
                      placement=placement, prefetch=prefetch)
            runner(module, data, warm, **kw)               # compile warm-up
            cells[cell] = _time_best(
                lambda: runner(module, data, pcfg, **kw), t_rounds, repeats)
    for placement in ("vmap", "sharded"):
        cell = f"sweep/{placement}"
        kw = dict(malicious=set(), seeds=seeds, placement=placement)
        run_pigeon_sweep(module, data, warm, **kw)
        cells[cell] = _time_best(
            lambda: run_pigeon_sweep(module, data, pcfg, **kw),
            t_rounds, repeats)

    for name in ("pigeon", "splitfed"):
        csv_row(f"placement_grid_{name}", cells[f"{name}/vmap"] * 1e3,
                f"vmap_ms={cells[name + '/vmap']:.1f};"
                f"sharded_ms={cells[name + '/sharded']:.1f};"
                f"prefetch_ms={cells[name + '/vmap+prefetch']:.1f}")
    csv_row("placement_grid_sweep", cells["sweep/vmap"] * 1e3,
            f"vmap_ms={cells['sweep/vmap']:.1f};"
            f"sharded_ms={cells['sweep/sharded']:.1f};seeds={len(seeds)}")

    import jax
    out = {"params": dict(M=m, N=3, d_m=d_m, E=2, B=32, rounds=t_rounds,
                          repeats=repeats, seeds=list(seeds),
                          devices=jax.device_count()),
           "cells_ms_per_round": cells}
    save_result("placement_grid", out)
    return out


if __name__ == "__main__":
    run()
