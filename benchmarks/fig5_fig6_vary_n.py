"""Figs. 5-6: vanilla SL vs Pigeon-SL+ for varying N (number of tolerated
malicious clients).  Paper: MNIST N in {1,3,5} (M=12), CIFAR N in {1,4,9}
(M=20); reduced mode uses M=8/N in {1,3} and M=10/N in {1,4}."""
from __future__ import annotations

import dataclasses

from repro.core import Attack, LABEL_FLIP, from_cnn, run_pigeon, run_vanilla_sl
from repro.data import build_image_task

from .common import (RoundTimer, cifar_scale, csv_row, mnist_scale, pcfg_from,
                     save_result)


def _run_dataset(name: str, scale, n_values, seed: int):
    data, cnn_cfg = build_image_task(name if name != "cifar" else "cifar10",
                                     m_clients=scale.m, d_m=scale.d_m,
                                     d_o=scale.d_o, n_test=scale.n_test,
                                     seed=seed)
    module = from_cnn(cnn_cfg)
    curves = {}
    attack = Attack(LABEL_FLIP)
    us = 0.0
    for n in n_values:
        if scale.m % (n + 1) != 0:
            continue            # paper: R must divide M
        pcfg = pcfg_from(scale, seed, n=n)
        malicious = set(range(n))
        with RoundTimer() as t:
            h_p = run_pigeon(module, data, pcfg, malicious, attack, plus=True)
        us = t.us_per(pcfg.T)
        h_v = run_vanilla_sl(module, data, pcfg, malicious, attack)
        curves[f"pigeon_plus_N{n}"] = h_p.series("test_acc")
        curves[f"vanilla_N{n}"] = h_v.series("test_acc")
    return curves, us


def run(full: bool = False, seed: int = 0):
    out = {}
    scale_m = mnist_scale(full)
    n_vals_m = (1, 3, 5) if full else (1, 3)
    curves_m, us_m = _run_dataset("mnist", scale_m, n_vals_m, seed)
    out["mnist"] = curves_m
    finals = {k: v[-1] for k, v in curves_m.items()}
    csv_row("fig5_mnist_vary_n", us_m,
            ";".join(f"{k}={v:.3f}" for k, v in sorted(finals.items())))

    scale_c = cifar_scale(full)
    if not full:
        # need M divisible by both R=2 and R=5 for the N sweep
        scale_c = dataclasses.replace(scale_c, m=10, t=4, e=3)
    n_vals_c = (1, 4, 9) if full else (1, 4)
    curves_c, us_c = _run_dataset("cifar", scale_c, n_vals_c, seed)
    out["cifar"] = curves_c
    finals = {k: v[-1] for k, v in curves_c.items()}
    csv_row("fig6_cifar_vary_n", us_c,
            ";".join(f"{k}={v:.3f}" for k, v in sorted(finals.items())))
    save_result("fig5_fig6_vary_n", out)
    return out


if __name__ == "__main__":
    run()
