"""Figs. 5-6: vanilla SL vs Pigeon-SL+ for varying N (number of tolerated
malicious clients).  Paper: MNIST N in {1,3,5} (M=12), CIFAR N in {1,4,9}
(M=20); reduced mode uses M=8/N in {1,3} and M=10/N in {1,4}.

Reduced-mode Pigeon runs use the batched cluster-parallel engine
(equivalence with the sequential reference is CI-tested, so the curves are
unchanged); --full runs stay on the sequential engine to bound memory; the
multi-seed variance band comes from ``run_pigeon_sweep``, which vmaps whole
protocol replicas over a seed axis."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (Attack, LABEL_FLIP, from_cnn, run_pigeon,
                        run_pigeon_sweep, run_vanilla_sl)
from repro.data import build_image_task

from .common import (RoundTimer, cifar_scale, csv_row, mnist_scale, pcfg_from,
                     save_result)


def _run_dataset(name: str, scale, n_values, seed: int, engine: str = "batched"):
    data, cnn_cfg = build_image_task(name if name != "cifar" else "cifar10",
                                     m_clients=scale.m, d_m=scale.d_m,
                                     d_o=scale.d_o, n_test=scale.n_test,
                                     seed=seed)
    module = from_cnn(cnn_cfg)
    curves = {}
    attack = Attack(LABEL_FLIP)
    us = 0.0
    for n in n_values:
        if scale.m % (n + 1) != 0:
            continue            # paper: R must divide M
        pcfg = pcfg_from(scale, seed, n=n)
        malicious = set(range(n))
        with RoundTimer() as t:
            h_p = run_pigeon(module, data, pcfg, malicious, attack, plus=True,
                             engine=engine)
        us = t.us_per(pcfg.T)
        h_v = run_vanilla_sl(module, data, pcfg, malicious, attack)
        curves[f"pigeon_plus_N{n}"] = h_p.series("test_acc")
        curves[f"vanilla_N{n}"] = h_v.series("test_acc")
    return curves, us


def _seed_sweep(name: str, scale, n: int, seeds) -> dict:
    """Final-accuracy mean/std across vmapped protocol replicas (Pigeon-SL,
    selection phase only — the sweep entry point trains S x R clusters per
    compiled round call)."""
    data, cnn_cfg = build_image_task(name if name != "cifar" else "cifar10",
                                     m_clients=scale.m, d_m=scale.d_m,
                                     d_o=scale.d_o, n_test=scale.n_test,
                                     seed=seeds[0])
    module = from_cnn(cnn_cfg)
    pcfg = pcfg_from(scale, seeds[0], n=n)
    with RoundTimer() as t:
        hists = run_pigeon_sweep(module, data, pcfg, malicious=set(range(n)),
                                 attack=Attack(LABEL_FLIP), seeds=seeds)
    finals = [h.rounds[-1]["test_acc"] for h in hists]
    return dict(seeds=list(seeds), final_accs=finals,
                mean=float(np.mean(finals)), std=float(np.std(finals)),
                variant="pigeon_sl_selection_only",
                us_per_round=t.us_per(pcfg.T))


def run(full: bool = False, seed: int = 0):
    out = {}
    # The batched engine materialises the whole round's (R, M_bar, E, B, ...)
    # batch stack at once; at the paper's --full CIFAR scale that is hundreds
    # of MB per round, so full mode stays on the sequential reference engine.
    engine = "sequential" if full else "batched"
    scale_m = mnist_scale(full)
    n_vals_m = (1, 3, 5) if full else (1, 3)
    curves_m, us_m = _run_dataset("mnist", scale_m, n_vals_m, seed, engine)
    out["mnist"] = curves_m
    finals = {k: v[-1] for k, v in curves_m.items()}
    csv_row("fig5_mnist_vary_n", us_m,
            ";".join(f"{k}={v:.3f}" for k, v in sorted(finals.items())))

    # multi-seed variance band for the headline MNIST N (vmapped replicas;
    # plain Pigeon-SL selection phase, not the plus variant the curves use).
    # Always at reduced scale: the sweep stacks (S, R, M_bar, E, B, ...)
    # batches per compiled round, which at paper scale would dwarf the
    # footprint the sequential fallback above bounds.
    sweep_seeds = tuple(range(3)) if full else (0, 1)
    sweep = _seed_sweep("mnist", mnist_scale(False), n_vals_m[0], sweep_seeds)
    out["mnist_seed_sweep"] = sweep
    csv_row("fig5_mnist_seed_sweep", sweep["us_per_round"],
            f"N={n_vals_m[0]};variant={sweep['variant']};"
            f"mean={sweep['mean']:.3f};std={sweep['std']:.3f};"
            f"seeds={len(sweep_seeds)}")

    scale_c = cifar_scale(full)
    if not full:
        # need M divisible by both R=2 and R=5 for the N sweep
        scale_c = dataclasses.replace(scale_c, m=10, t=4, e=3)
    n_vals_c = (1, 4, 9) if full else (1, 4)
    curves_c, us_c = _run_dataset("cifar", scale_c, n_vals_c, seed, engine)
    out["cifar"] = curves_c
    finals = {k: v[-1] for k, v in curves_c.items()}
    csv_row("fig6_cifar_vary_n", us_c,
            ";".join(f"{k}={v:.3f}" for k, v in sorted(finals.items())))
    save_result("fig5_fig6_vary_n", out)
    return out


if __name__ == "__main__":
    run()
