"""Table I: communication/computation overhead — measured message counts
from the protocol's CommMeter vs the paper's closed-form formulas.

  vanilla SL   : M*Dt*d_c + M*d_CL            | M*Dt*F_CL
  Pigeon-SL    : (M*Dt + 2R*Do)*d_c + M*d_CL  | (M*Dt + 2R*Do)*F_CL
  Pigeon-SL+   : ((2M-Mb)*Dt + 2R*Do)*d_c + (2M-Mb)*d_CL
                                              | ((2M-Mb)*Dt + 2R*Do)*F_CL
(Dt = E*B samples per client turn, Mb = M/R, F_CL = one client fwd+bwd.)

Also measures wall-clock round time of the sequential reference engine vs the
batched cluster-parallel engine (``engine_speedup``): both engines run the
same protocol from the same seeds (equivalence is CI-tested), so the ratio is
a pure execution-strategy comparison.  The win comes from collapsing the
R x M_bar per-client dispatch/sync chain into one compiled program, so it
grows with R and shrinks as per-client compute grows.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import (HONEST, ProtocolConfig, from_cnn, run_pigeon,
                        run_vanilla_sl)
from repro.core.protocol import _count_params, cut_width
from repro.data import build_image_task
from repro.telemetry import Stopwatch

from .common import RoundTimer, csv_row, save_result


def run(full: bool = False, seed: int = 0, telemetry=None):
    """``telemetry`` (an optional :class:`repro.telemetry.Telemetry`) traces
    the three accounting runs only — never the ``engine_speedup`` timing
    loops, whose numbers must not absorb sink I/O."""
    data, cnn_cfg = build_image_task("mnist", m_clients=8, d_m=300, d_o=150,
                                     n_test=500, seed=seed)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=8, N=3, T=1, E=5, B=32, lr=0.03, seed=seed)
    gamma0, _ = module.init(jax.random.PRNGKey(0))
    d_cl = _count_params(gamma0)
    d_c = cut_width(module, gamma0, data.x0)
    d_o = data.x0.shape[0]
    dt = pcfg.E * pcfg.B
    m, r = pcfg.M, pcfg.R
    mb = m // r

    rows = []
    with RoundTimer() as t:
        h = run_vanilla_sl(module, data, pcfg, malicious=set(),
                           telemetry=telemetry)
    c = h.rounds[0]["comm"]
    rows.append(("vanilla_sl",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=m * dt * d_c + m * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=m * dt)))
    us = t.us_per(1)

    h = run_pigeon(module, data, pcfg, malicious=set(), telemetry=telemetry)
    c = h.rounds[0]["comm"]
    rows.append(("pigeon_sl",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=(m * dt + 2 * r * d_o) * d_c + m * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=m * dt + 2 * r * d_o)))

    h = run_pigeon(module, data, pcfg, malicious=set(), plus=True,
                   telemetry=telemetry)
    c = h.rounds[0]["comm"]
    rows.append(("pigeon_sl_plus",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=((2 * m - mb) * dt + 2 * r * d_o) * d_c
                      + (2 * m - mb) * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=(2 * m - mb) * dt + 2 * r * d_o)))

    out = {"params": dict(M=m, R=r, E=pcfg.E, B=pcfg.B, d_c=d_c, d_cl=d_cl,
                          D_o=d_o), "rows": dict(rows)}
    for name, row in rows:
        match = (row["measured_comm"] == row["formula_comm"]
                 and row["measured_comp"] == row["formula_comp"])
        csv_row(f"table1_{name}", us,
                f"comm_measured={row['measured_comm']};"
                f"comm_formula={row['formula_comm']};match={match}")
    out["engine_speedup"] = engine_speedup(full=full, seed=seed)
    save_result("table1_overhead", out)
    return out


def engine_speedup(full: bool = False, seed: int = 0):
    """Sequential vs batched round time across an R-sweep (the CommMeter
    columns above are engine-independent; this is the wall-clock column).

    The configs scan the protocol-simulation regime the paper's figures run
    in: many clusters, modest per-client compute.  The dispatch-bound corner
    (large R, small E) is where the batched engine clears 2x on CPU.

    ``run_pigeon`` unavoidably evaluates at t=0 and t=T-1; a tiny test set
    keeps that engine-independent cost out of the measured round times.
    """
    data, cnn_cfg = build_image_task("mnist", m_clients=16, d_m=150, d_o=64,
                                     n_test=32, seed=seed)
    module = from_cnn(cnn_cfg)
    timed_rounds = 6 if not full else 16
    repeats = 3
    grid = [  # (N, E, B) with M=16; R = N+1
        (3, 2, 8),
        (7, 2, 8),
        (15, 2, 8),
        (15, 1, 4),      # dispatch-bound corner: many clusters, small batches
    ]
    results = {}
    for n, e, b in grid:
        pcfg = ProtocolConfig(M=16, N=n, T=timed_rounds, E=e, B=b, lr=0.03,
                              seed=seed, eval_every=10 * timed_rounds)
        ms = {}
        for engine in ("sequential", "batched"):
            warm = dataclasses.replace(pcfg, T=2)
            run_pigeon(module, data, warm, malicious=set(), engine=engine)
            best = float("inf")
            for _ in range(repeats):     # best-of-N vs scheduler noise
                with Stopwatch() as sw:
                    run_pigeon(module, data, pcfg, malicious=set(),
                               engine=engine)
                best = min(best, sw.elapsed / pcfg.T * 1e3)
            ms[engine] = best
        speedup = ms["sequential"] / ms["batched"]
        results[f"R{n + 1}_E{e}_B{b}"] = dict(
            sequential_ms=ms["sequential"], batched_ms=ms["batched"],
            speedup=speedup)
        csv_row(f"engine_speedup_R{n + 1}_E{e}_B{b}", ms["batched"] * 1e3,
                f"seq_ms={ms['sequential']:.1f};bat_ms={ms['batched']:.1f};"
                f"speedup={speedup:.2f}x")
    return results


if __name__ == "__main__":
    run()
