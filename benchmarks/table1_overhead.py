"""Table I: communication/computation overhead — measured message counts
from the protocol's CommMeter vs the paper's closed-form formulas.

  vanilla SL   : M*Dt*d_c + M*d_CL            | M*Dt*F_CL
  Pigeon-SL    : (M*Dt + 2R*Do)*d_c + M*d_CL  | (M*Dt + 2R*Do)*F_CL
  Pigeon-SL+   : ((2M-Mb)*Dt + 2R*Do)*d_c + (2M-Mb)*d_CL
                                              | ((2M-Mb)*Dt + 2R*Do)*F_CL
(Dt = E*B samples per client turn, Mb = M/R, F_CL = one client fwd+bwd.)
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import (HONEST, ProtocolConfig, from_cnn, run_pigeon,
                        run_vanilla_sl)
from repro.core.protocol import _count_params, cut_width
from repro.data import build_image_task

from .common import RoundTimer, csv_row, save_result


def run(full: bool = False, seed: int = 0):
    data, cnn_cfg = build_image_task("mnist", m_clients=8, d_m=300, d_o=150,
                                     n_test=500, seed=seed)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=8, N=3, T=1, E=5, B=32, lr=0.03, seed=seed)
    gamma0, _ = module.init(jax.random.PRNGKey(0))
    d_cl = _count_params(gamma0)
    d_c = cut_width(module, gamma0, data.x0)
    d_o = data.x0.shape[0]
    dt = pcfg.E * pcfg.B
    m, r = pcfg.M, pcfg.R
    mb = m // r

    rows = []
    with RoundTimer() as t:
        h = run_vanilla_sl(module, data, pcfg, malicious=set())
    c = h.rounds[0]["comm"]
    rows.append(("vanilla_sl",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=m * dt * d_c + m * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=m * dt)))
    us = t.us_per(1)

    h = run_pigeon(module, data, pcfg, malicious=set())
    c = h.rounds[0]["comm"]
    rows.append(("pigeon_sl",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=(m * dt + 2 * r * d_o) * d_c + m * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=m * dt + 2 * r * d_o)))

    h = run_pigeon(module, data, pcfg, malicious=set(), plus=True)
    c = h.rounds[0]["comm"]
    rows.append(("pigeon_sl_plus",
                 dict(measured_comm=c["activation_floats"] + c["param_floats"]
                      + c["validation_floats"],
                      formula_comm=((2 * m - mb) * dt + 2 * r * d_o) * d_c
                      + (2 * m - mb) * d_cl,
                      measured_comp=c["client_passes"],
                      formula_comp=(2 * m - mb) * dt + 2 * r * d_o)))

    out = {"params": dict(M=m, R=r, E=pcfg.E, B=pcfg.B, d_c=d_c, d_cl=d_cl,
                          D_o=d_o), "rows": dict(rows)}
    for name, row in rows:
        match = (row["measured_comm"] == row["formula_comm"]
                 and row["measured_comp"] == row["formula_comp"])
        csv_row(f"table1_{name}", us,
                f"comm_measured={row['measured_comm']};"
                f"comm_formula={row['formula_comm']};match={match}")
    save_result("table1_overhead", out)
    return out


if __name__ == "__main__":
    run()
