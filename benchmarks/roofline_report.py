"""§Roofline report: formats experiments/dryrun_results.json into the
per-(arch x shape x mesh) three-term table consumed by EXPERIMENTS.md."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from .common import EXP_DIR, csv_row

RESULTS = os.path.join(EXP_DIR, "dryrun_results.json")


def load() -> List[Dict]:
    with open(RESULTS) as f:
        return json.load(f)


def fmt_row(r: Dict) -> str:
    rl = r["roofline"]
    mem = r["memory"]
    args_gb = (mem["argument_bytes"] or 0) / 2**30
    return (f"| {r['arch']} | {r['shape']} | {r['mesh'].split('(')[0]} "
            f"| {r['program']} "
            f"| {rl['compute_s']:.2e} | {rl['memory_s']:.2e} "
            f"| {rl['collective_s']:.2e} | **{rl['dominant']}** "
            f"| {rl['model_flops']:.2e} | {rl['useful_ratio']:.3f} "
            f"| {args_gb:.1f} |")


def run(markdown: bool = True):
    recs = load()
    done = [r for r in recs if r.get("ok")]
    skipped = [r for r in recs if r.get("skipped")]
    failed = [r for r in recs if not r.get("ok") and not r.get("skipped")]
    if markdown:
        print("| arch | shape | mesh | program | compute_s | memory_s "
              "| collective_s | dominant | model_flops | useful | args_GiB |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in sorted(done, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
            print(fmt_row(r))
        for r in skipped:
            print(f"| {r['arch']} | {r['shape']} | - | SKIP: {r['reason'][:60]} "
                  f"| | | | | | | |")
    n_single = len([r for r in done if "pod" not in r["mesh"]])
    n_multi = len([r for r in done if "pod" in r["mesh"]])
    csv_row("roofline_report", 0,
            f"ok_single={n_single};ok_multi={n_multi};failed={len(failed)};"
            f"skipped={len(skipped)}")
    return done, failed, skipped


if __name__ == "__main__":
    run()
