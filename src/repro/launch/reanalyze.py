"""Re-run the static HLO analysis over saved .hlo.txt dumps and refresh the
hlo/roofline fields in dryrun_results.json — lets accounting fixes apply to
every recorded combo without recompiling.

  PYTHONPATH=src python -m repro.launch.reanalyze \
      --hlo-dir experiments/hlo --out experiments/dryrun_results.json
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from ..configs import get_config
from . import hlo_analysis
from .roofline import roofline_terms
from .shapes import SHAPES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun_results.json")
    args = ap.parse_args()

    with open(args.out) as f:
        recs = json.load(f)

    n_updated = 0
    for rec in recs:
        if not rec.get("ok"):
            continue
        tag = "multi" if "pod" in rec["mesh"] else "single"
        opts = ""
        if "+" in rec.get("program", ""):
            opts = "+" + "+".join(rec["program"].split("+")[1:])
        path = os.path.join(args.hlo_dir,
                            f"{rec['arch']}_{rec['shape']}_{tag}{opts}.hlo.txt")
        if not os.path.exists(path):
            continue
        with open(path) as f:
            ha = hlo_analysis.analyze_hlo(f.read())
        rec["hlo"] = {
            "flops_per_device": ha.flops,
            "bytes_per_device": ha.bytes,
            "collective_bytes_per_device": ha.coll_bytes,
            "collectives_by_kind": {k: round(v) for k, v in ha.coll_by_kind.items()},
            "collective_counts": ha.coll_count,
        }
        shape = SHAPES[rec["shape"]]
        cfg = get_config(rec["arch"])
        tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" \
            else shape.global_batch
        rl = roofline_terms(ha.flops, ha.bytes, ha.coll_bytes, rec["chips"],
                            shape.kind, cfg.active_param_count(), tokens)
        rec["roofline"] = rl.as_dict()
        n_updated += 1

    with open(args.out, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"re-analyzed {n_updated} records -> {args.out}")


if __name__ == "__main__":
    main()
