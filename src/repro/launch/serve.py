"""Serving launcher: batched autoregressive decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32

Greedy-decodes a batch of synthetic prompts through the smoke-scale model
(the full configs lower the same serve_step on the production mesh via
repro.launch.dryrun)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_smoke_config, list_archs
from ..data import make_markov_tokens
from ..models import build_model
from ..telemetry import Stopwatch, Telemetry
from .steps import instrument_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a JSONL span trace of every decode step")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_seq = args.prompt_len + args.new_tokens
    cache = model.init_cache(args.batch, max_seq)

    prompts = make_markov_tokens(args.seed, cfg.vocab, args.batch,
                                 args.prompt_len)
    memory = None
    if cfg.arch_type in ("audio", "encdec"):
        memory = 0.1 * jnp.ones((args.batch, 8, cfg.d_model))

    decode = jax.jit(
        lambda p, c, t, i: model.decode_step(p, c, t, i, memory),
        donate_argnums=(1,))

    tel = None
    if args.trace:
        tel = Telemetry(jsonl=args.trace).session(
            "serve", arch=cfg.name, batch=args.batch,
            prompt_len=args.prompt_len, new_tokens=args.new_tokens)
        decode = instrument_step(decode, tel, "serve.decode")

    # prefill by stepping the prompt through the decode path
    with Stopwatch() as sw:
        tok = jnp.asarray(prompts[:, :1])
        for i in range(args.prompt_len):
            logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]), i)
        generated = []
        for j in range(args.new_tokens):
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            generated.append(np.asarray(tok))
            logits, cache = decode(params, cache, tok, args.prompt_len + j)
    if tel is not None:
        tel.close()
    gen = np.concatenate(generated, axis=1)
    total_tokens = args.batch * (args.prompt_len + args.new_tokens)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"throughput: {total_tokens / sw.elapsed:.1f} tok/s (CPU, smoke scale)")
    for b in range(min(args.batch, 2)):
        print(f"  sample[{b}]: prompt={prompts[b].tolist()} "
              f"-> {gen[b][:16].tolist()}...")


if __name__ == "__main__":
    main()
