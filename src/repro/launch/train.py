"""Training launcher.

Runs the Pigeon-SL protocol (or a baseline) over any registered architecture
at smoke scale on CPU, or over the paper's CNNs:

  PYTHONPATH=src python -m repro.launch.train --task mnist --protocol pigeon+ \
      --attack label_flip --malicious 2 --rounds 10
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --protocol pigeon --attack gradient --rounds 3

The full-size configs are trained via the dry-run/production path (pjit on
the 16x16 mesh) — on this CPU container only the reduced variants execute.
"""
from __future__ import annotations

import argparse
import json

from ..configs import get_smoke_config, list_archs
from ..core import (Attack, HONEST, ProtocolConfig, from_cnn, from_lm,
                    run_pigeon, run_splitfed, run_vanilla_sl)
from ..data import build_image_task, build_lm_task
from ..models import build_model
from ..telemetry import Stopwatch, Telemetry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default=None, choices=["mnist", "cifar10"])
    ap.add_argument("--arch", default=None, choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (required on CPU)")
    ap.add_argument("--protocol", default="pigeon+",
                    choices=["pigeon", "pigeon+", "vanilla", "sfl"])
    ap.add_argument("--attack", default="none",
                    choices=["none", "label_flip", "activation", "gradient",
                             "param_tamper"])
    ap.add_argument("--malicious", type=int, default=0,
                    help="number of malicious clients (first k ids)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tolerance", type=int, default=1,
                    help="N, the malicious-client budget (R = N+1)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-steps", type=int, default=5, help="E")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a JSONL telemetry trace (spans + per-round "
                         "metrics + provenance) to PATH")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of round 1 into DIR")
    ap.add_argument("--engine", default=None,
                    choices=["sequential", "batched"],
                    help="round engine (default: batched when --block > 1, "
                         "else sequential)")
    ap.add_argument("--block", type=int, default=1,
                    help="round-block size: scan this many rounds on device "
                         "per host sync (pigeon/sfl batched engine only; "
                         "pigeon+ and param_tamper force 1)")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable JAX's persistent compilation cache in DIR "
                         "(default: $REPRO_COMPILE_CACHE if set)")
    args = ap.parse_args()

    from ..core import enable_compile_cache
    enable_compile_cache(args.compile_cache)   # no-op when dir/env unset

    engine = args.engine or ("batched" if args.block > 1 else "sequential")

    if args.task:
        data, cnn_cfg = build_image_task(args.task, m_clients=args.clients,
                                         d_m=300, d_o=150, n_test=1000,
                                         seed=args.seed)
        module = from_cnn(cnn_cfg)
        lr = args.lr or (0.05 if args.task == "mnist" else 0.02)
    else:
        arch = args.arch or "qwen3-8b"
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        module = from_lm(model)
        data = build_lm_task(vocab=cfg.vocab, seq_len=32,
                             m_clients=args.clients, d_m=64, d_o=32,
                             n_test=32, seed=args.seed)
        lr = args.lr or 5e-2

    pcfg = ProtocolConfig(M=args.clients, N=args.tolerance, T=args.rounds,
                          E=args.local_steps, B=args.batch, lr=lr,
                          seed=args.seed)
    attack = HONEST if args.attack == "none" else Attack(args.attack)
    malicious = set(range(args.malicious))
    telemetry = None
    if args.trace or args.profile_dir:
        telemetry = Telemetry(jsonl=args.trace, profile_dir=args.profile_dir)

    with Stopwatch() as sw:
        if args.protocol == "vanilla":
            hist = run_vanilla_sl(module, data, pcfg, malicious, attack,
                                  verbose=True, telemetry=telemetry)
        elif args.protocol == "sfl":
            hist = run_splitfed(module, data, pcfg, malicious, attack,
                                verbose=True, telemetry=telemetry,
                                engine=engine, block=args.block)
        else:
            hist = run_pigeon(module, data, pcfg, malicious, attack,
                              plus=args.protocol == "pigeon+", verbose=True,
                              telemetry=telemetry, engine=engine,
                              block=args.block)
    final = hist.rounds[-1].get("test_acc")
    print(f"done: {args.protocol} rounds={args.rounds} "
          f"final_test_acc={final} wall={sw.elapsed:.1f}s")
    if args.trace:
        print(f"telemetry trace: {args.trace}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist.rounds, f, indent=1, default=str)


if __name__ == "__main__":
    main()
