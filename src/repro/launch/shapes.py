"""The four assigned input shapes and per-(arch, shape) applicability.

``long_500k`` requires sub-quadratic attention: it runs for the SSM/hybrid
architectures (zamba2, xlstm) and for the dense architectures with a
sliding-window variant (gemma3 5:1 local:global, h2o-danube SWA); it is
skipped for pure full-attention architectures (qwen2.5-14b, qwen3-8b,
qwen3-moe, deepseek-v2-lite, internvl2, seamless) — recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# architectures allowed to run long_500k (sub-quadratic or SWA)
SUBQUADRATIC = {"zamba2-1.2b", "xlstm-1.3b", "gemma3-12b", "h2o-danube-1.8b"}


def applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """Returns (runs?, reason-if-skipped)."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""


def shape_settings(shape: InputShape) -> Dict[str, object]:
    """Execution knobs applied to the ModelConfig per input shape."""
    if shape.kind == "train":
        return dict(q_chunk=512, loss_chunk=512, remat=True,
                    ssm_chunk=512, dtype="bfloat16")
    if shape.kind == "prefill":
        return dict(q_chunk=2048, loss_chunk=0, remat=False,
                    ssm_chunk=2048, dtype="bfloat16")
    return dict(q_chunk=0, loss_chunk=0, remat=False, dtype="bfloat16")
