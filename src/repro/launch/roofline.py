"""Roofline model (TPU v5e): the three terms per (arch, shape, mesh).

  compute term    = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory term     = HLO_bytes / (chips * 819 GB/s)
  collective term = collective_bytes / (chips * 50 GB/s)

FLOPs/bytes/collective_bytes are *global* (per-device analysis x chips);
dividing by chips recovers the per-device time.  MODEL_FLOPS is the analytic
6*N*D (train) / 2*N*D (prefill/decode) with N = active params, giving the
useful-compute ratio that catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops_for(kind: str, active_params: int, tokens: int) -> float:
    """Analytic model FLOPs for the step (global, all chips)."""
    if kind == "train":
        return 6.0 * active_params * tokens
    # prefill and decode are forward-only
    return 2.0 * active_params * tokens


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_coll_bytes: float, chips: int,
                   kind: str, active_params: int, tokens: int) -> Roofline:
    compute_s = per_device_flops / PEAK_FLOPS_BF16
    memory_s = per_device_bytes / HBM_BW
    coll_s = per_device_coll_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1])[0]
    mf = model_flops_for(kind, active_params, tokens)
    global_flops = per_device_flops * chips
    return Roofline(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_global=global_flops,
        useful_ratio=(mf / global_flops) if global_flops else 0.0,
    )
