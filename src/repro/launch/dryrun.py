import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: AOT-lower and compile every (architecture x input
shape) combination on the production meshes, record memory / cost /
collective analyses and the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun_results.json

The single-pod mesh (16x16, data x model) lowers the per-cluster SL step —
the program each Pigeon cluster runs independently.  The multi-pod mesh
(2x16x16, pod x data x model) lowers the full ``pigeon_round_step`` for the
train shape (cluster replicas sharded over "pod", validation-argmin-select
and winner broadcast across pods) and pod-extended data parallelism for the
inference shapes — proving the "pod" axis shards.
"""
import argparse
import dataclasses
import json
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..configs import get_config, list_archs
from ..telemetry import Stopwatch
from . import hlo_analysis
from .mesh import make_production_mesh
from .roofline import model_flops_for, roofline_terms
from .shapes import SHAPES, applicable
from .steps import apply_shape_settings, input_specs


def lower_and_compile(spec, save_hlo: Optional[str] = None) -> Dict[str, Any]:
    # Stopwatch = monotonic perf_counter; time.time() can step under NTP and
    # produced occasional negative lower/compile durations in CI logs.
    with Stopwatch() as sw_lower:
        jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                         out_shardings=spec.out_shardings)
        lowered = jitted.lower(*spec.args)
    with Stopwatch() as sw_compile:
        compiled = lowered.compile()
    t_lower, t_compile = sw_lower.elapsed, sw_compile.elapsed
    if save_hlo:
        os.makedirs(os.path.dirname(save_hlo), exist_ok=True)
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_txt = compiled.as_text()
    ha = hlo_analysis.analyze_hlo(hlo_txt)
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis": {
            "flops_body_once": ca.get("flops"),
            "bytes_body_once": ca.get("bytes accessed"),
        },
        "hlo": {
            "flops_per_device": ha.flops,
            "bytes_per_device": ha.bytes,
            "collective_bytes_per_device": ha.coll_bytes,
            "collectives_by_kind": {k: round(v) for k, v in ha.coll_by_kind.items()},
            "collective_counts": ha.coll_count,
        },
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            seq_shard_cache: bool = False, pigeon_clusters: Optional[int] = None,
            save_hlo_dir: Optional[str] = None,
            optimizations: tuple = ()) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    # the multi-pod train program is the full Pigeon round (R=2 clusters,
    # one per pod); the single-pod program is one cluster's SL step.
    if pigeon_clusters is None:
        pigeon_clusters = 2 if (multi_pod and shape.kind == "train") else 0

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16(pod,data,model)" if multi_pod else "16x16(data,model)",
        "chips": chips,
        "program": ("pigeon_round_step" if pigeon_clusters else
                    {"train": "train_step", "prefill": "prefill_step",
                     "decode": "serve_step"}[shape.kind])
                   + ("".join(f"+{o}" for o in optimizations))
                   + ("+seq_shard_cache" if seq_shard_cache else ""),
    }
    try:
        save_hlo = None
        if save_hlo_dir:
            tag = "multi" if multi_pod else "single"
            tag += "".join(f"+{o}" for o in optimizations)
            save_hlo = os.path.join(save_hlo_dir, f"{arch}_{shape_name}_{tag}.hlo.txt")
        with mesh:
            spec = input_specs(cfg, shape_name, mesh,
                               pigeon_clusters=pigeon_clusters,
                               seq_shard_cache=seq_shard_cache,
                               optimizations=optimizations)
            rec.update(lower_and_compile(spec, save_hlo))
        rec["ok"] = True
        # roofline (single-pod table is the baseline record)
        tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" \
            else shape.global_batch
        rl = roofline_terms(rec["hlo"]["flops_per_device"],
                            rec["hlo"]["bytes_per_device"],
                            rec["hlo"]["collective_bytes_per_device"],
                            chips, shape.kind, cfg.active_param_count(), tokens)
        rec["roofline"] = rl.as_dict()
    except Exception as e:  # noqa: BLE001 — failures are bugs; record them
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-shard-cache", action="store_true",
                    help="flash-decoding cache layout (perf variant)")
    ap.add_argument("--out", default=None, help="append results to this JSON file")
    ap.add_argument("--save-hlo", default=None, metavar="DIR",
                    help="dump optimized HLO text per combo into DIR")
    ap.add_argument("--opt", action="append", default=[],
                    help="named optimization(s): moe_shard, pigeon_shardmap, "
                         "mlstm_bf16_state (repeatable; pigeon_psum retired "
                         "— the one-hot psum broadcast is now built in)")
    ap.add_argument("--no-pigeon", action="store_true",
                    help="multi-pod train: lower plain data-parallel "
                         "train_step instead of pigeon_round_step (control)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape_name in shapes:
            ok, reason = applicable(arch, shape_name)
            if not ok:
                results.append({"arch": arch, "shape": shape_name,
                                "skipped": True, "reason": reason})
                print(f"SKIP  {arch:24s} {shape_name:12s} {reason}")
                continue
            for mp in meshes:
                rec = run_one(arch, shape_name, mp,
                              seq_shard_cache=args.seq_shard_cache,
                              save_hlo_dir=args.save_hlo,
                              optimizations=tuple(args.opt),
                              pigeon_clusters=0 if args.no_pigeon else None)
                results.append(rec)
                status = "OK " if rec.get("ok") else "FAIL"
                extra = ""
                if rec.get("ok"):
                    r = rec["roofline"]
                    extra = (f"dom={r['dominant']:10s} "
                             f"c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s "
                             f"x={r['collective_s']:.2e}s "
                             f"compile={rec['compile_s']:.0f}s")
                else:
                    extra = rec.get("error", "")[:120]
                print(f"{status}  {arch:24s} {shape_name:12s} "
                      f"{rec['mesh']:22s} {extra}", flush=True)

    if args.out:
        existing = []
        if os.path.exists(args.out):
            with open(args.out) as f:
                existing = json.load(f)
        # replace same-key records
        def key(r):
            return (r.get("arch"), r.get("shape"), r.get("mesh"), r.get("program"))
        merged = {key(r): r for r in existing}
        for r in results:
            merged[key(r)] = r
        with open(args.out, "w") as f:
            json.dump(list(merged.values()), f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
