"""Production mesh definitions (TPU v5e target).

A pod is a 16x16 = 256-chip slice with ("data", "model") axes; the two-pod
production job adds a leading "pod" axis.  In the Pigeon-SL mapping the
"pod" axis carries *cluster parallelism*: with R = N + 1 = 2 clusters each
pod trains one cluster's split network independently, and the cluster
selection (argmin validation loss + parameter broadcast) is the only
cross-pod collective — exactly the paper's communication pattern.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axis names that carry the batch dimension."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


# TPU v5e hardware constants (per chip) for the roofline model.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
