"""Sharding rules: params / batches / decode caches -> NamedSharding.

Strategy (baseline, GSPMD-propagated):
  * batch dims over ("pod","data") — pure data parallelism across pods
    unless the pod axis is carrying Pigeon clusters (see steps.pigeon_round);
  * weight matrices tensor-parallel over "model": the FFN/attention
    projection *output* dim for the up-projections, the *input* dim for the
    down-projections (Megatron pattern: one all-reduce per block);
  * MoE expert banks expert-parallel over "model" (experts % 16 == 0 for
    both MoE archs);
  * vocab (embedding rows / head columns) over "model";
  * everything small (norms, biases, gates, conv kernels) replicated.

A dim is only sharded when divisible by the axis size; otherwise the rule
falls through to replication — GSPMD then picks the collectives.  Leaves
inside a layer stack have a leading layer dim which is never sharded.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# leaf-name patterns -> which logical dim gets the "model" axis.
# dims are indexed from the END of the shape so stacked leading dims are
# transparent ("-1" = last dim, "-2" = second-to-last).
_RULES = [
    (r"embed$", -2),                    # (V, D) shard vocab rows
    (r"head/w$", -1),                   # (D, V) shard vocab cols
    (r"(wq|wk|wv)/w$", -1),             # (D, H*hd) shard heads-out
    (r"(wq|wk|wv)/b$", -1),
    (r"wo/w$", -2),                     # (H*hd, D) shard heads-in
    (r"(gate|up)/w$", -1),              # (D, F) shard ffn-out
    (r"down/w$", -2),                   # (F, D) shard ffn-in
    (r"moe/(gate|up)$", -3),            # (E, D, F) expert parallel
    (r"moe/down$", -3),                 # (E, F, D) expert parallel
    (r"shared/(gate|up)/w$", -1),
    (r"shared/down/w$", -2),
    (r"in_proj/w$", -1),                # mamba (D, d_in_proj)
    (r"out_proj/w$", -2),               # mamba (di, D)
    (r"w_dkv/w$", -1),                  # MLA down-proj
    (r"(w_uk|w_uv)/w$", -1),            # MLA up-proj (rank, H*hd)
    (r"w_if/w$", -1),
    (r"r$", None),                      # slstm recurrent: replicate
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _spec_for_leaf(path: str, shape: Tuple[int, ...], model_size: int,
                   model_axis: str = "model", cluster_axis: Optional[str] = None,
                   cluster_dim: bool = False) -> P:
    """cluster_dim: the leaf carries a leading cluster-replica dim (sharded
    over cluster_axis); the name rules then apply to the remaining dims."""
    ndim = len(shape)
    lead = 1 if (cluster_dim and cluster_axis is not None) else 0
    spec = [None] * ndim
    for pat, dim in _RULES:
        if re.search(pat, path):
            if dim is not None:
                d = ndim + dim
                if lead <= d < ndim and shape[d] % model_size == 0 and shape[d] >= model_size:
                    spec[d] = model_axis
            break
    if lead:
        spec[0] = cluster_axis
    return P(*spec)


def param_shardings(params_shape: Pytree, mesh: Mesh,
                    cluster_axis: Optional[str] = None) -> Pytree:
    """Build NamedShardings for a params pytree (of ShapeDtypeStructs or
    arrays).  If ``cluster_axis`` is given, every leaf is assumed to carry a
    leading cluster-replica dim sharded over that axis (the multi-pod
    Pigeon layout)."""
    model_size = mesh.shape["model"]

    def one(path, leaf):
        spec = _spec_for_leaf(_path_str(path), tuple(leaf.shape), model_size,
                              cluster_axis=cluster_axis,
                              cluster_dim=cluster_axis is not None)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(batch_shape: Pytree, mesh: Mesh,
                    cluster_axis: Optional[str] = None) -> Pytree:
    """Batch dim over ("pod","data") (or ("data",) on one pod).  If
    cluster_axis is set, a leading cluster dim is sharded over it and the
    batch goes over the remaining data axes."""
    dp = [n for n in mesh.axis_names if n in ("pod", "data") and n != cluster_axis]
    dp_axes = tuple(dp) if len(dp) > 1 else (dp[0] if dp else None)

    def one(leaf):
        spec = [dp_axes] + [None] * (len(leaf.shape) - 1)
        if cluster_axis is not None:
            spec = [cluster_axis] + spec[:len(leaf.shape) - 1]
        return NamedSharding(mesh, P(*spec[: len(leaf.shape)]))

    return jax.tree.map(one, batch_shape)


def cache_shardings(cache_shape: Pytree, mesh: Mesh, batch: int,
                    seq_shard: bool = False) -> Pytree:
    """Decode-cache shardings.

    Default: shard the cache batch dim over ("pod","data") when divisible,
    the kv-heads dim over "model" when divisible, else replicate.
    ``seq_shard=True`` (long-context flash-decoding layout) shards the
    *sequence* dim of attention caches over the data axes instead — the
    layout consumed by the shard_map decode-attention optimisation.
    """
    dp = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    model_size = mesh.shape["model"]
    dp_axes = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        spec = [None] * len(shape)
        # stacked layer dim first for stacked caches: (L, B, S, H, hd)
        bdim = 1 if len(shape) >= 2 and shape[0] != batch else 0
        if "k" == name.split("/")[-1] or "v" == name.split("/")[-1] \
                or "latent" in name or "k_rope" in name:
            sdim = bdim + 1
            if seq_shard and shape[sdim] % dp_size == 0:
                spec[sdim] = dp_axes
            elif shape[bdim] % dp_size == 0:
                spec[bdim] = dp_axes
            # kv-heads over model if present and divisible; otherwise fall
            # back to sharding the cache sequence over "model" (kv=8 heads
            # cannot split over 16) so a 32k cache still fits HBM
            if len(shape) >= sdim + 3 and shape[sdim + 1] % model_size == 0:
                spec[sdim + 1] = "model"
            elif spec[sdim] is None and shape[sdim] % model_size == 0:
                spec[sdim] = "model"
        else:
            # recurrent states: (L, B, H, P, N) — batch over data, heads over model
            if shape[bdim] % dp_size == 0:
                spec[bdim] = dp_axes
            if len(shape) > bdim + 1 and shape[bdim + 1] % model_size == 0:
                spec[bdim + 1] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pigeon_sweep_shardings(stacked_params: Pytree, batches: Pytree,
                           val_batch: Pytree, mesh: Mesh,
                           seed_axis: str = "seed",
                           cluster_axis: str = "pod"
                           ) -> Tuple[Pytree, Pytree, Pytree]:
    """The (params, batches, val) sharding triple of the multi-seed sweep
    round: per-seed carried params lead with the seed axis, per-replica
    batches with (seed, cluster), and the shared set D_o replicated (every
    replica validates the same data) but sharded over any intra-replica
    "data" axis, mirroring :func:`pigeon_round_shardings`."""
    p_shard = param_shardings(stacked_params, mesh, cluster_axis=seed_axis)
    lead = (seed_axis, cluster_axis)

    def one(leaf):
        spec = list(lead[: leaf.ndim]) + [None] * (leaf.ndim - 2)
        return NamedSharding(mesh, P(*spec[: leaf.ndim]))

    b_shard = jax.tree.map(one, batches)
    data_ax = "data" if "data" in mesh.axis_names else None
    v_shard = jax.tree.map(
        lambda x: NamedSharding(mesh, P(data_ax, *([None] * (x.ndim - 1)))),
        val_batch)
    return p_shard, b_shard, v_shard


def pigeon_round_shardings(stacked_params: Pytree, batches: Pytree,
                           val_batch: Pytree, mesh: Mesh,
                           cluster_axis: str = "pod") -> Tuple[Pytree, Pytree, Pytree]:
    """The (params, batches, val) sharding triple of a Pigeon round step:
    stacked cluster replicas and per-cluster batches over the cluster axis,
    and the shared set D_o replicated across pods (every cluster validates
    the same data — §III-C) but sharded over the data axis *within* a pod —
    leaving it fully replicated makes GSPMD replicate the validation forward
    once per device (§Perf hillclimb C it.4)."""
    p_shard = param_shardings(stacked_params, mesh, cluster_axis=cluster_axis)
    b_shard = batch_shardings(batches, mesh, cluster_axis=cluster_axis)
    v_shard = jax.tree.map(
        lambda x: NamedSharding(mesh, P("data", *([None] * (x.ndim - 1)))),
        val_batch)
    return p_shard, b_shard, v_shard
