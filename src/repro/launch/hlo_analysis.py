"""Static analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body exactly once
(verified empirically in EXPERIMENTS.md §Dry-run), which under-counts
scan-over-layers models by ~n_layers.  This analyzer re-derives the roofline
terms from the HLO text with loop trip-count multiplication:

  * FLOPs      — from ``dot`` / ``convolution`` instructions (2*M*N*K), the
                 only FLOP-dense ops in these models;
  * bytes      — per top-level instruction, operand-bytes + result-bytes
                 (fusion bodies excluded: they never touch HBM);
  * collective — per collective instruction, the per-device bytes moved
                 (ring estimates: all-reduce 2x, all-gather/reduce-scatter
                 (g-1)/g x gathered size, all-to-all 1x, collective-permute
                 1x), multiplied through loop trip counts.

Trip counts come from the loop condition computation (the scan bound is the
max s32 constant compared against).  All counts are per-device (the HLO is
the per-partition SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_RE = re.compile(r"known_trip_count\W+n\W+(\d+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str                       # operand list + attrs


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    is_entry: bool = False
    param_types: Dict[str, str] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(name=m.group(2), instrs=[],
                                  is_entry=bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        line = _COMMENT_RE.sub("", line)
        mi = _INSTR_RE.match(line)
        if mi:
            cur.instrs.append(Instr(mi.group(1), mi.group(2).strip(),
                                    mi.group(3), mi.group(4)))
    return comps


def _operand_names(rest: str) -> List[str]:
    # operands are the leading %refs before the closing paren of the op call
    depth, out, i = 1, [], 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    arglist = rest[: i - 1]
    return re.findall(r"%([\w.\-]+)", arglist)


def _group_size(rest: str, default: int) -> int:
    # replica_groups=[8,4]<=[32]  -> group size 4 ... (iota format)
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    # replica_groups={{0,1,2,3},...} -> size of first group
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "custom-call", "iota", "while",
                   "conditional", "call", "partition-id", "replica-id"}


def _instr_bytes(ins: Instr, rbytes: int, operand_bytes: List[int]) -> float:
    """HBM-traffic estimate for one top-level instruction.

    ``dynamic-update-slice`` (and fusions rooted in one — XLA's in-place
    while-loop stash pattern) writes only the updated slice, so the full
    buffer operand must not be counted per iteration; likewise a fusion
    containing ``slice``/``dynamic-slice`` of a big buffer (scan reading its
    per-step xs) only touches the slice it produces, not the whole operand
    — without this rule an sLSTM time-scan is over-counted ~1000x
    (EXPERIMENTS.md §Perf, hillclimb B diagnosis)."""
    name_or_op = ins.name + " " + ins.op
    total_ops = float(sum(operand_bytes))
    largest = float(max(operand_bytes)) if operand_bytes else 0.0
    if "dynamic-update-slice" in name_or_op or "scatter" in name_or_op:
        # in-place window write: update + indices read, window written
        return 2.0 * (total_ops - largest)
    if "slice" in name_or_op or "gather" in name_or_op:
        # only the produced window is touched in the big operand(s)
        small = sum(o for o in operand_bytes if o <= 4 * max(rbytes, 1))
        return 2.0 * rbytes + small
    if ins.op == "fusion" and "reduce" not in name_or_op:
        # generic fusion: an operand vastly larger than the result is a
        # buffer the fusion slices internally (scan stash reads) — cap each
        # operand at ~result size; reductions legitimately read everything.
        cap = max(4.0 * rbytes, float(1 << 20))
        return rbytes + sum(min(float(o), cap) for o in operand_bytes)
    return rbytes + total_ops


def analyze_computation(comp: Computation, types: Dict[str, str]) -> Tuple[CompCost, List[Tuple[str, str, float]]]:
    """Returns (local cost, calls=[(kind, callee, mult_hint)])."""
    cost = CompCost()
    calls: List[Tuple[str, str, float]] = []
    # local symbol table
    local_types = dict(types)
    for ins in comp.instrs:
        local_types[ins.name] = ins.result_type
    for ins in comp.instrs:
        op = ins.op
        rtype = ins.result_type
        rbytes = _type_bytes(rtype)
        opnames = _operand_names(ins.rest)
        operand_bytes = [_type_bytes(local_types.get(o, "")) for o in opnames]
        obytes = sum(operand_bytes)

        if op == "dot":
            out_elems = 1
            for d in _shape_dims(rtype):
                out_elems *= d
            lhs_dims = _shape_dims(local_types.get(opnames[0], "")) if opnames else []
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
            k = 1
            if m and m.group(1) and lhs_dims:
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(lhs_dims):
                        k *= lhs_dims[di]
            cost.flops += 2.0 * out_elems * k
        elif op == "convolution":
            out_elems = 1
            for d in _shape_dims(rtype):
                out_elems *= d
            rhs_dims = _shape_dims(local_types.get(opnames[1], "")) if len(opnames) > 1 else []
            k = 1
            for d in rhs_dims[:-1]:
                k *= d
            cost.flops += 2.0 * out_elems * k

        if op in COLLECTIVES:
            g = _group_size(ins.rest, 2)
            if op == "all-reduce":
                moved = 2.0 * rbytes * (g - 1) / g
            elif op == "all-gather":
                moved = rbytes * (g - 1) / g
            elif op == "reduce-scatter":
                moved = obytes * (g - 1) / g
            elif op == "all-to-all":
                moved = rbytes * (g - 1) / g
            else:  # collective-permute
                moved = rbytes
            cost.coll_bytes += moved
            cost.coll_by_kind[op] = cost.coll_by_kind.get(op, 0.0) + moved
            cost.coll_count[op] = cost.coll_count.get(op, 0) + 1

        if op not in _SKIP_BYTES_OPS:
            cost.bytes += _instr_bytes(ins, rbytes, operand_bytes)

        # sub-computation references
        m = re.search(r"calls=%?([\w.\-]+)", ins.rest)
        if m:
            calls.append(("fusion", m.group(1), 1.0))
        if op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            mt = _TRIP_RE.search(ins.rest)
            hint = float(mt.group(1)) if mt else 0.0   # 0 => derive from cond
            if mb and mc:
                calls.append(("while", mb.group(1), hint))
                calls.append(("while_cond", mc.group(1), hint))
        if op in ("call", "conditional", "async-start"):
            mt = re.search(r"to_apply=%?([\w.\-]+)", ins.rest)
            if mt:
                calls.append(("call", mt.group(1), 1.0))
            for mm in re.finditer(r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-,% ]+)", ins.rest):
                for nm in re.findall(r"%?([\w.\-]+)", mm.group(1)):
                    calls.append(("call", nm, 1.0))
    return cost, calls


def trip_count(comp: Computation) -> int:
    """Max s32 constant in the loop condition — the scan bound."""
    best = 1
    for ins in comp.instrs:
        if ins.op == "constant" and ins.result_type.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HLOAnalysis:
    flops: float
    bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, float]
    coll_count: Dict[str, int]


def analyze_hlo(hlo: str) -> HLOAnalysis:
    comps = parse_computations(hlo)
    # pre-compute local costs and call lists
    infos = {name: analyze_computation(c, {}) for name, c in comps.items()}

    memo: Dict[str, CompCost] = {}

    def total(name: str, seen=()) -> CompCost:
        if name in memo:
            return memo[name]
        if name not in infos or name in seen:
            return CompCost()
        local, calls = infos[name]
        agg = CompCost(local.flops, local.bytes, local.coll_bytes,
                       dict(local.coll_by_kind), dict(local.coll_count))
        pending_body: Optional[str] = None
        for kind, callee, hint in calls:
            if kind == "while":
                pending_body = callee
            elif kind == "while_cond":
                n = hint or (trip_count(comps[callee]) if callee in comps else 1)
                if pending_body:
                    sub = total(pending_body, seen + (name,))
                    _accumulate(agg, sub, n)
                    pending_body = None
                sub = total(callee, seen + (name,))
                _accumulate(agg, sub, n)
            elif kind == "fusion":
                # fusion bodies never touch HBM: count their FLOPs, not bytes
                sub = total(callee, seen + (name,))
                _accumulate(agg, sub, 1, include_bytes=False)
            else:
                sub = total(callee, seen + (name,))
                _accumulate(agg, sub, 1)
        if pending_body:   # while with body parsed after cond or missing cond
            sub = total(pending_body, seen + (name,))
            _accumulate(agg, sub, 1)
        memo[name] = agg
        return agg

    entry = next((c.name for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HLOAnalysis(0, 0, 0, {}, {})
    agg = total(entry)
    return HLOAnalysis(agg.flops, agg.bytes, agg.coll_bytes,
                       agg.coll_by_kind, agg.coll_count)


HOST_TRANSFER_OPS = ("outfeed", "infeed", "send", "recv", "send-done",
                     "recv-done")


def host_transfer_counts(hlo: str) -> Dict[str, int]:
    """Counts of device<->host channel ops and host-callback custom-calls
    across every computation of the module.  The static program auditor
    (``repro.analysis``) pins these to zero for device round programs: the
    only data that may leave the device is the jit outputs themselves (the
    stacked round/block fetch)."""
    comps = parse_computations(hlo)
    out: Dict[str, int] = {op: 0 for op in HOST_TRANSFER_OPS}
    out["host_callback"] = 0
    out["custom_call"] = 0
    out["instructions"] = 0
    for comp in comps.values():
        for ins in comp.instrs:
            out["instructions"] += 1
            if ins.op in HOST_TRANSFER_OPS:
                out[ins.op] += 1
            elif ins.op == "custom-call":
                out["custom_call"] += 1
                if "callback" in ins.rest:
                    out["host_callback"] += 1
    return out


def _accumulate(agg: CompCost, sub: CompCost, mult: float,
                include_bytes: bool = True) -> None:
    agg.flops += sub.flops * mult
    if include_bytes:
        agg.bytes += sub.bytes * mult
    agg.coll_bytes += sub.coll_bytes * mult
    for k, v in sub.coll_by_kind.items():
        agg.coll_by_kind[k] = agg.coll_by_kind.get(k, 0.0) + v * mult
    for k, v in sub.coll_count.items():
        agg.coll_count[k] = agg.coll_count.get(k, 0) + int(v * mult)
