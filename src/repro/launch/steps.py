"""The compiled step functions and their ShapeDtypeStruct input specs.

Four programs cover the assigned (arch x shape) grid:

  * ``train_step``        — one SL mini-batch update of a cluster's split
                            network (client + AP halves fused into one SPMD
                            program; the cut is a logical boundary).
  * ``prefill_step``      — full-sequence forward, last-token logits.
  * ``serve_step``        — ONE new token against a seq_len KV cache.
  * ``pigeon_round_step`` — the multi-pod program: R cluster replicas
                            stacked on a leading dim (sharded over the "pod"
                            axis), per-cluster SGD update + shared-set
                            validation loss + argmin selection + broadcast
                            of the winning parameters — the paper's entire
                            global round as one SPMD program.

The pigeon round makers are thin adapters over
``repro.core.runner.RoundRunner`` — this module only supplies the
model-level train/validate binding (:func:`launch_round_spec`) and the
sharding specs; the round body (train + validate + argmin + winner
broadcast) is the same single source of truth the protocol engine runs.

``input_specs(arch, shape, mesh)`` builds the matching ShapeDtypeStruct
stand-ins (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.runner import RoundRunner, RoundSpec
from ..models import build_model
from ..models.config import ModelConfig
from ..models.model import Model
from . import shardings as shd
from .shapes import SHAPES, InputShape, shape_settings

Pytree = Any


def instrument_step(fn: Callable, telemetry, name: str) -> Callable:
    """Wrap a compiled step so every call emits one telemetry span.

    ``telemetry`` is a :class:`repro.telemetry.TelemetrySession` (or None /
    a null session, in which case ``fn`` is returned untouched — zero
    overhead when tracing is off).  The span fences on the step's outputs
    (``block_until_ready``, no transfer), so its duration covers the device
    execution the async dispatch would otherwise hide."""
    if telemetry is None or not getattr(telemetry, "enabled", False):
        return fn

    calls = iter(range(1 << 62))

    def traced(*args, **kwargs):
        with telemetry.span(name, call=next(calls)) as sp:
            out = fn(*args, **kwargs)
            sp.fence(out)
            return out

    return traced


# ---------------------------------------------------------------------------
# batch spec construction
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: InputShape, cluster_dim: int = 0) -> Dict[str, Any]:
    """ShapeDtypeStructs for one training/prefill batch."""
    b, s = shape.global_batch, shape.seq_len
    lead = (cluster_dim,) if cluster_dim else ()
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.dtype]
    if cfg.arch_type == "vlm":
        npx = cfg.n_prefix_tokens
        return {
            "patches": jax.ShapeDtypeStruct(lead + (b, npx, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct(lead + (b, s - npx), jnp.int32),
            "labels": jax.ShapeDtypeStruct(lead + (b, s - npx), jnp.int32),
        }
    if cfg.arch_type in ("audio", "encdec"):
        s_half = s // 2
        return {
            "frames": jax.ShapeDtypeStruct(lead + (b, s_half, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct(lead + (b, s_half), jnp.int32),
            "labels": jax.ShapeDtypeStruct(lead + (b, s_half), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct(lead + (b, s), jnp.int32),
    }


def decode_structs(cfg: ModelConfig, model: Model, shape: InputShape):
    """(tokens, index, cache, memory?) ShapeDtypeStructs for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.dtype]
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(b, s, dt))
    memory = None
    if cfg.arch_type in ("audio", "encdec"):
        memory = jax.ShapeDtypeStruct((b, min(4096, s // 8), cfg.d_model), dt)
    return tokens, index, cache, memory


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model: Model, lr: float = 1e-3,
                    quant: Optional[str] = None) -> Callable:
    """One fused SPMD train step.  With ``quant`` the loss routes through the
    model's gamma/phi cut and ``kernels.ops.quant_cut_exchange`` — a
    straight-through wire model whose forward quantizes the uplink activation
    message and whose backward quantizes the downlink cut-gradient cotangent,
    so this single ``value_and_grad`` sees exactly the two messages a real
    AP/client pair would exchange.  ``quant=None`` keeps the plain
    ``model.loss`` path bit-for-bit."""

    def loss_fn_of(batch):
        if quant is None:
            return lambda p: model.loss(p, batch)
        from ..kernels import ops as kops

        def loss_fn(p):
            gamma, phi = model.split_params(p)
            acts = model.client_forward(gamma, batch)
            acts = kops.quant_cut_exchange(acts, quant)
            return model.ap_forward(phi, acts, batch)

        return loss_fn

    def train_step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn_of(batch), has_aux=True)(params)
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new_params, loss
    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        h, _ = model.forward(params, batch)
        # last-position logits — the serving prefill output
        return (h[:, -1:, :] @ params["head"]["w"]).astype(jnp.float32)
    return prefill_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, cache, tokens, index, memory=None):
        logits, new_cache = model.decode_step(params, cache, tokens, index, memory)
        return logits.astype(jnp.float32), new_cache
    return serve_step


def launch_round_spec(model: Model, lr: float = 1e-3,
                      constrain_val: bool = False,
                      quant: Optional[str] = None) -> "RoundSpec":
    """The launch-layer binding of the RoundRunner's RoundSpec: one SPMD
    train step per cluster and the shared-set validation loss.  With
    ``constrain_val`` the validation forward is pinned to the (auto) "data"
    axis — leaving it unconstrained inside a manual pod shard_map makes
    GSPMD replicate the forward per device (§Perf hillclimb C it.4).

    ``validate_sharded`` slices the validation batch into (up to) k equal
    shards for the median-of-means selection family; there is no
    ``message_stats`` hook — the launch layer runs plain SPMD train steps,
    not the SL message exchange — so anomaly-scoring policies
    (loss_plus_distance) are rejected at build time with a clear error.

    ``quant`` applies the straight-through quantized cut-layer wire to the
    per-cluster train steps only — the shared-set validation forward stays
    exact (it is the defense-critical message; see :mod:`repro.core.comm`)."""
    train = make_train_step(model, lr, quant=quant)

    def _constrain(val_batch):
        if constrain_val:
            val_batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P("data", *([None] * (x.ndim - 1)))), val_batch)
        return val_batch

    def validate(params, val_batch):
        vloss, _ = model.loss(params, _constrain(val_batch))
        return vloss, None

    def validate_sharded(params, val_batch, k):
        from ..selection import effective_shards
        val_batch = _constrain(val_batch)
        b = jax.tree.leaves(val_batch)[0].shape[0]
        kk = effective_shards(k, b)
        shards = jax.tree.map(
            lambda x: x.reshape((kk, b // kk) + x.shape[1:]), val_batch)
        losses = jax.vmap(lambda vb: model.loss(params, vb)[0])(shards)
        # the reported vloss stays the exact full-batch loss: Model.loss is
        # a valid-token-weighted (masked) mean, so a mean of per-shard means
        # would over-weight padding-light shards; the shards feed only the
        # median-of-means score
        vloss, _ = model.loss(params, val_batch)
        return vloss, losses, None

    def train_summary(aux):
        return aux            # (R,) per-cluster train loss

    return RoundSpec(train, validate, validate_sharded=validate_sharded,
                     train_summary=train_summary)


def make_pigeon_round_step_shardmap(model: Model, mesh, lr: float = 1e-3,
                                    for_execution: bool = False,
                                    selection: str = "argmin",
                                    quant: Optional[str] = None,
                                    block: int = 1) -> Callable:
    """Cluster parallelism as a *manual* pod-axis shard_map (§Perf hillclimb
    C iteration 3): each pod runs its cluster slice's train+validate program
    (data/model axes stay GSPMD-auto), and the only cross-pod collectives
    are the R-sized loss all-gather and the winner psum.  This is the
    RoundRunner's ``placement="sharded"``; the vmap variant below shares the
    same round body.

    ``for_execution=True`` gates the CPU + partial-auto combination up front
    (XLA CPU has no PartitionId under SPMD, so auto axes of size > 1 crash at
    run time with an inscrutable error).  The default leaves the gate off
    because the dry-run driver only lowers/compiles this step — that is
    supported on every backend.

    ``block > 1`` returns the round-block program instead
    (:meth:`RoundRunner.round_block_fn`): K scanned rounds whose ``batches``
    argument leads with the K round axis, returning ``(rebro_params,
    (vlosses_KR, sels_K))`` — one dispatch and one fetch per K rounds."""
    from ..core.runner import check_partial_auto_backend
    from ..selection import resolve_policy
    if block < 1:
        raise ValueError(f"block={block} must be >= 1")
    if for_execution:
        check_partial_auto_backend(mesh, ("pod",))
    runner = RoundRunner(launch_round_spec(model, lr, constrain_val=True,
                                           quant=quant),
                         placement="sharded", mesh=mesh, params_stacked=True,
                         select=resolve_policy(selection))
    return runner.round_block_fn() if block > 1 else runner.round_fn()


def make_pigeon_plus_round_step(model: Model, lr: float = 1e-3,
                                quant: Optional[str] = None) -> Callable:
    """Beyond-paper Pigeon-SL+ round for the multi-pod mapping.

    Paper's Pigeon-SL+ trains ONLY the selected cluster for R-1 extra
    sub-rounds — on the pod mapping that leaves R-1 pods idle.  Here the
    extra sub-round trains the winner on BOTH pods data-parallel (each pod
    contributes gradients from its own sub-batch; one cross-pod grad
    all-reduce), so the + phase runs at full-fleet throughput while keeping
    the paper's semantics (extra updates flow only into the winning
    cluster's parameters).
    """
    base = make_pigeon_round_step(model, lr, quant=quant)

    def plus_round(stacked_params, batches, val_batch, plus_batches):
        rebro, vlosses, sel = base(stacked_params, batches, val_batch)
        # all cluster slots now hold the winner; the extra sub-round is a
        # plain DP step over (pod, data): treat the cluster dim of
        # plus_batches as additional batch parallelism.
        def one(params, batch):
            new_params, loss = make_train_step(model, lr, quant=quant)(params,
                                                                       batch)
            return new_params, loss

        new_stacked, losses = jax.vmap(one)(rebro, plus_batches)
        # average the replicas' updates (they started identical, trained on
        # different data => params differ by their grad contributions)
        mean_params = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
            new_stacked)
        out = jax.tree.map(
            lambda m, full: jnp.broadcast_to(m[None], full.shape).astype(full.dtype),
            mean_params, new_stacked)
        return out, vlosses, sel

    return plus_round


def make_pigeon_round_step(model: Model, lr: float = 1e-3,
                           selection: str = "argmin",
                           quant: Optional[str] = None,
                           block: int = 1) -> Callable:
    """One Pigeon-SL global round over R stacked cluster replicas (R is
    inferred from the stacked leading dim at trace time).

    stacked_params: every leaf has leading dim R (sharded over "pod").
    batches:        (R, B, S) per-cluster token batches.
    val_batch:      shared D_o batch, replicated — each cluster evaluates the
                    same reference set (Section III-C).
    Returns (new_stacked_params, val_losses, selected_idx).

    Thin adapter over the RoundRunner's vmap placement — train + validate +
    policy selection + winner broadcast all come from ``core/runner.py``,
    the same body the protocol engine runs; ``selection`` names any
    loss-based ``repro.selection`` policy (argmin / median_of_means /
    trimmed — the same knob as the protocol drivers).  The winner broadcast
    is always the one-hot psum contraction (a single masked all-reduce per
    leaf instead of the gather+full-replicate path GSPMD emits for dynamic
    indexing), which retired the "pigeon_psum" named optimization — it is
    the only strategy.

    ``block > 1`` returns the round-block program instead
    (:meth:`RoundRunner.round_block_fn`): all round inputs gain a leading
    K axis and the step runs K rounds as one ``lax.scan``, returning
    ``(new_stacked_params, (val_losses_KR, selected_K))``.
    """
    from ..selection import resolve_policy
    if block < 1:
        raise ValueError(f"block={block} must be >= 1")
    runner = RoundRunner(launch_round_spec(model, lr, quant=quant),
                         placement="vmap", params_stacked=True,
                         select=resolve_policy(selection))
    return runner.round_block_fn() if block > 1 else runner.round_fn()


# ---------------------------------------------------------------------------
# input_specs — everything dryrun.py needs to lower one (arch, shape, mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweringSpec:
    fn: Callable
    args: Tuple                 # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def apply_shape_settings(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    return dataclasses.replace(cfg, **shape_settings(shape))


def input_specs(cfg: ModelConfig, shape_name: str, mesh, *,
                pigeon_clusters: int = 0, lr: float = 1e-3,
                seq_shard_cache: bool = False,
                optimizations: Tuple[str, ...] = (),
                selection: str = "argmin",
                quant: Optional[str] = None) -> LoweringSpec:
    """Build the (fn, ShapeDtypeStruct args, shardings) triple for one
    (architecture x input-shape x mesh) combination.  ``selection`` names
    the loss-based selection policy the pigeon round steps compile in;
    ``quant`` compiles the quantized cut-layer wire into the train steps
    (train/pigeon shapes only — prefill/decode have no cut exchange)."""
    shape = SHAPES[shape_name]
    cfg = apply_shape_settings(cfg, shape)
    if optimizations:
        cfg = dataclasses.replace(
            cfg, optimizations=tuple(cfg.optimizations) + tuple(optimizations))
    model = build_model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))

    if shape.kind == "train":
        if pigeon_clusters:
            r = pigeon_clusters
            stacked = jax.tree.map(lambda x: jax.ShapeDtypeStruct((r,) + x.shape, x.dtype),
                                   params_shape)
            # "pigeon_batch_split": each cluster trains global_batch/R, so
            # the robust round costs the same tokens/step as plain DP
            # (§Perf hillclimb C iteration 2)
            per_cluster_b = (shape.global_batch // r
                             if "pigeon_batch_split" in cfg.optimizations
                             else shape.global_batch)
            batches = batch_struct(cfg, dataclasses.replace(
                shape, global_batch=per_cluster_b), cluster_dim=r)
            val_shape = dataclasses.replace(shape, global_batch=max(
                16, shape.global_batch // 8))
            val_batch = batch_struct(cfg, val_shape)
            p_shard, b_shard, v_shard = shd.pigeon_round_shardings(
                stacked, batches, val_batch, mesh, cluster_axis="pod")
            if "pigeon_plus" in cfg.optimizations:
                fn = make_pigeon_plus_round_step(model, lr, quant=quant)
                plus_batches = batch_struct(cfg, dataclasses.replace(
                    shape, global_batch=per_cluster_b), cluster_dim=r)
                pb_shard = shd.batch_shardings(plus_batches, mesh,
                                               cluster_axis="pod")
                return LoweringSpec(fn, (stacked, batches, val_batch, plus_batches),
                                    (p_shard, b_shard, v_shard, pb_shard), None)
            if "pigeon_shardmap" in cfg.optimizations:
                # dryrun only lowers/compiles this spec; anyone *executing*
                # it should build the step with for_execution=True (or call
                # check_partial_auto_backend) — CPU + auto axes > 1 cannot run
                fn = make_pigeon_round_step_shardmap(model, mesh, lr,
                                                     selection=selection,
                                                     quant=quant)
            else:
                fn = make_pigeon_round_step(model, lr, selection=selection,
                                            quant=quant)
            return LoweringSpec(fn, (stacked, batches, val_batch),
                                (p_shard, b_shard, v_shard), None)
        p_shard = shd.param_shardings(params_shape, mesh)
        batch = batch_struct(cfg, shape)
        b_shard = shd.batch_shardings(batch, mesh)
        fn = make_train_step(model, lr, quant=quant)
        return LoweringSpec(fn, (params_shape, batch), (p_shard, b_shard), None)

    if shape.kind == "prefill":
        p_shard = shd.param_shardings(params_shape, mesh)
        batch = batch_struct(cfg, shape)
        b_shard = shd.batch_shardings(batch, mesh)
        fn = make_prefill_step(model)
        return LoweringSpec(fn, (params_shape, batch), (p_shard, b_shard), None)

    # decode
    tokens, index, cache, memory = decode_structs(cfg, model, shape)
    p_shard = shd.param_shardings(params_shape, mesh)
    c_shard = shd.cache_shardings(cache, mesh, shape.global_batch,
                                  seq_shard=seq_shard_cache or shape.global_batch == 1)
    t_shard = shd.batch_shardings({"t": tokens}, mesh)["t"] \
        if shape.global_batch % np.prod([mesh.shape[a] for a in mesh.axis_names
                                         if a in ("pod", "data")]) == 0 \
        else shd.replicated(mesh)
    i_shard = shd.replicated(mesh)
    fn = make_serve_step(model)
    args = (params_shape, cache, tokens, index)
    in_sh = (p_shard, c_shard, t_shard, i_shard)
    if memory is not None:
        args = args + (memory,)
        in_sh = in_sh + (shd.batch_shardings({"m": memory}, mesh)["m"],)
    return LoweringSpec(fn, args, in_sh, None)
