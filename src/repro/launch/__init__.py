# NOTE: deliberately does NOT import dryrun (which sets
# XLA_FLAGS/device-count); import submodules explicitly.
