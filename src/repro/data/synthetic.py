"""Synthetic data generation.

The container has no MNIST/CIFAR files (repro band <= 2: data gate), so the
image classification tasks are simulated with *class-template Gaussian*
data: each class c has a fixed smooth template image t_c; a sample is
``a * t_c + sigma * noise`` with per-sample amplitude jitter.  This keeps the
paper's experimental structure intact — a CNN learns it quickly, label
flipping / activation / gradient tampering degrade it in the same qualitative
way — while being fully reproducible offline.  (Documented in DESIGN.md.)

Token data for LM smoke tests is a deterministic-ish Markov-chain language
so that next-token loss is learnable above chance.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def _smooth(img: np.ndarray, k: int = 3, iters: int = 2) -> np.ndarray:
    """Cheap box-blur smoothing to make templates low-frequency."""
    for _ in range(iters):
        pad = np.pad(img, (((k - 1) // 2, k // 2), ((k - 1) // 2, k // 2), (0, 0)),
                     mode="edge")
        acc = np.zeros_like(img)
        for dy in range(k):
            for dx in range(k):
                acc += pad[dy : dy + img.shape[0], dx : dx + img.shape[1], :]
        img = acc / (k * k)
    return img


def make_templates(rng: np.random.Generator, n_classes: int, size: int,
                   channels: int) -> np.ndarray:
    t = rng.normal(0, 1, (n_classes, size, size, channels)).astype(np.float32)
    t = np.stack([_smooth(x) for x in t])
    t /= np.maximum(np.abs(t).max(axis=(1, 2, 3), keepdims=True), 1e-6)
    return t


def sample_images(rng: np.random.Generator, templates: np.ndarray, n: int,
                  noise: float = 0.35) -> Tuple[np.ndarray, np.ndarray]:
    n_classes = templates.shape[0]
    y = rng.integers(0, n_classes, size=n)
    amp = rng.uniform(0.7, 1.3, size=(n, 1, 1, 1)).astype(np.float32)
    x = amp * templates[y] + noise * rng.normal(0, 1, (n,) + templates.shape[1:]).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)


def make_classification_data(seed: int, n_classes: int, size: int, channels: int,
                             m_clients: int, d_m: int, d_o: int, n_test: int,
                             noise: float = 0.35):
    """Returns a ``repro.core.ClientData``-shaped tuple of arrays."""
    rng = np.random.default_rng(seed)
    templates = make_templates(rng, n_classes, size, channels)
    xs, ys = [], []
    for _ in range(m_clients):
        x, y = sample_images(rng, templates, d_m, noise)
        xs.append(x)
        ys.append(y)
    x0, y0 = sample_images(rng, templates, d_o, noise)
    xt, yt = sample_images(rng, templates, n_test, noise)
    return (np.stack(xs), np.stack(ys), x0, y0, xt, yt)


# ---------------------------------------------------------------------------
# token data (Markov language) for LM training demos
# ---------------------------------------------------------------------------

def make_markov_tokens(seed: int, vocab: int, n_seqs: int, seq_len: int,
                       order_bias: float = 6.0) -> np.ndarray:
    """Sequences from a random but strongly-peaked Markov chain: next-token
    prediction is learnable well above uniform."""
    rng = np.random.default_rng(seed)
    logits = rng.normal(0, 1, (vocab, vocab)) * order_bias
    probs = np.exp(logits - logits.max(axis=1, keepdims=True))
    probs /= probs.sum(axis=1, keepdims=True)
    out = np.empty((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seqs)
    for t in range(seq_len):
        out[:, t] = state
        u = rng.random((n_seqs, 1))
        state = (probs[state].cumsum(axis=1) > u).argmax(axis=1)
    return out


def lm_batch(tokens: np.ndarray):
    """tokens (N, S+1) -> inputs/labels for next-token prediction."""
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
