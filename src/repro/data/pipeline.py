"""Data pipeline: client sharding, shared validation set, batching and the
double-buffered host-side round feeder.

The pipeline mirrors the paper's system model: client m holds a local shard
D_m (i.i.d. from p(x, y)); the AP samples the shared/reference set D_o from
the same distribution and broadcasts it before training.  The
:class:`RoundFeeder` overlaps the host-side assembly of round t+1 (batch
gathering, RNG/key derivation, device transfer) with device execution of
round t — cluster selection is the protocol's only true sync point."""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Iterator, Optional, Tuple

import numpy as np

from ..core.protocol import ClientData
from ..telemetry import NULL_SESSION
from . import synthetic


def dirichlet_relabel(data: ClientData, alpha: float, seed: int = 0) -> ClientData:
    """Beyond-paper non-IID ablation: resample each client's shard with a
    Dirichlet(alpha) class prior (alpha -> inf recovers the paper's i.i.d.
    assumption; alpha ~ 0.1 gives heavily skewed clients).  The shared set
    D_o and the test set stay i.i.d. — the AP draws them from p(x, y)."""
    rng = np.random.default_rng(seed)
    m = data.x.shape[0]
    n_classes = int(data.y.max()) + 1
    pool_x = data.x.reshape(-1, *data.x.shape[2:])
    pool_y = data.y.reshape(-1)
    by_class = [np.where(pool_y == c)[0] for c in range(n_classes)]
    d_m = data.x.shape[1]
    xs, ys = [], []
    for _ in range(m):
        prior = rng.dirichlet([alpha] * n_classes)
        counts = rng.multinomial(d_m, prior)
        idx = np.concatenate([
            rng.choice(by_class[c], size=k, replace=True)
            for c, k in enumerate(counts) if k > 0])
        rng.shuffle(idx)
        xs.append(pool_x[idx])
        ys.append(pool_y[idx])
    return ClientData(x=np.stack(xs), y=np.stack(ys), x0=data.x0, y0=data.y0,
                      x_test=data.x_test, y_test=data.y_test)


def build_image_task(name: str, m_clients: int, d_m: int, d_o: int,
                     n_test: int = 7000, seed: int = 0) -> Tuple[ClientData, "object"]:
    """name: 'mnist' | 'cifar10' — returns (ClientData, CNNConfig)."""
    from ..models.cnn import CIFAR_CNN, MNIST_CNN
    if name == "mnist":
        cfg = MNIST_CNN
        arrs = synthetic.make_classification_data(seed, 10, 28, 1, m_clients, d_m,
                                                  d_o, n_test)
    elif name == "cifar10":
        # lower noise: the deeper CNN gets far fewer updates at reduced
        # scale, so the synthetic task carries more class signal
        cfg = CIFAR_CNN
        arrs = synthetic.make_classification_data(seed, 10, 32, 3, m_clients, d_m,
                                                  d_o, n_test, noise=0.25)
    else:
        raise ValueError(name)
    x, y, x0, y0, xt, yt = arrs
    return ClientData(x=x, y=y, x0=x0, y0=y0, x_test=xt, y_test=yt), cfg


def build_lm_task(vocab: int, seq_len: int, m_clients: int, d_m: int, d_o: int,
                  n_test: int = 64, seed: int = 0) -> ClientData:
    """Token-sequence task for running the protocol over transformer models.
    x arrays hold input tokens; y arrays hold next-token labels."""
    toks = synthetic.make_markov_tokens(seed, vocab, m_clients * d_m + d_o + n_test,
                                        seq_len + 1)
    x_all, y_all = toks[:, :-1], toks[:, 1:]
    n_cl = m_clients * d_m
    x = x_all[:n_cl].reshape(m_clients, d_m, seq_len)
    y = y_all[:n_cl].reshape(m_clients, d_m, seq_len)
    x0 = x_all[n_cl : n_cl + d_o]
    y0 = y_all[n_cl : n_cl + d_o]
    xt = x_all[n_cl + d_o :]
    yt = y_all[n_cl + d_o :]
    return ClientData(x=x, y=y, x0=x0, y0=y0, x_test=xt, y_test=yt)


def minibatches(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
                batch: int, steps: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    for _ in range(steps):
        idx = rng.integers(0, x.shape[0], size=batch)
        yield x[idx], y[idx]


# ---------------------------------------------------------------------------
# double-buffered host pipeline
# ---------------------------------------------------------------------------

def plan_blocks(start: int, stop: int, block: int,
                is_sync: Optional[Callable[[int], bool]] = None):
    """Partition rounds ``[start, stop)`` into ``(t0, k)`` segments of at
    most ``block`` consecutive rounds for round-block execution.

    A segment never extends past a *sync round* — a round whose post-state
    the host must observe before the next round may run (an eval round, a
    checkpoint round): each segment ENDS at the first sync round it reaches,
    because a scanned block only surfaces theta at its final round.
    ``is_sync(t)`` returns whether round ``t`` is such a sync point (``None``
    = no sync constraints); ``block=1`` degenerates to one segment per
    round.  Segments tile ``[start, stop)`` exactly, in order."""
    if block < 1:
        raise ValueError(f"block={block} must be >= 1")
    segments = []
    t = start
    while t < stop:
        k = lane_block_len(t, stop, block, is_sync)
        segments.append((t, k))
        t += k
    return segments


def lane_block_len(t: int, stop: int, block: int,
                   is_sync: Optional[Callable[[int], bool]] = None) -> int:
    """Length of the :func:`plan_blocks` segment starting at round ``t`` —
    the one copy of the sync-round-terminates-segment rule, shared with the
    job-pool scheduler, which re-evaluates it per lane every pool block (a
    pool block runs ``min`` over its active lanes' segment lengths, so a
    lane's sync rounds always land on the last round that lane executes)."""
    k = 1
    while (k < block and t + k < stop
           and not (is_sync is not None and is_sync(t + k - 1))):
        k += 1
    return k

class RoundFeeder:
    """Double-buffered host-side round assembly.

    ``make_round(t)`` — the consumer-supplied closure that samples one
    round's payload (for Pigeon-SL: clusters, stacked mini-batches, derived
    per-client keys, attack state) — is executed on ONE background thread
    strictly in ascending-``t`` order.  That preserves the numpy-RNG and
    JAX-key consumption order the sequential-oracle equivalence contract
    depends on: the streams see exactly the calls the synchronous path would
    make, just earlier in wall-clock time.  Device transfers issued inside
    ``make_round`` (``jnp.asarray`` / ``jax.device_put``) are asynchronous,
    so they overlap with the device executing the current round.

    At most ``depth`` assembled rounds wait in the queue ahead of the
    consumer (``depth=1`` is classic double buffering).  ``depth=0``
    degrades to fully synchronous assembly — the bound the protocol drivers
    apply at Pigeon-SL+ phase boundaries, where sub-round sampling depends
    on the selected cluster and nothing may run ahead of selection.
    SplitFed's sampling is selection-independent (no sub-rounds, no
    tamper-check key splits), so ``run_splitfed`` reuses the feeder at full
    depth under every threat model.

    ``make_round`` may return arbitrary payloads; ``run_pigeon`` includes a
    per-round randomness-stream snapshot so checkpoints written while the
    feeder runs ahead still capture the synchronous end-of-round state (the
    on-stream resume contract).

    Exceptions raised inside ``make_round`` are re-raised from :meth:`get`
    at the round that failed.  Always :meth:`close` (or use as a context
    manager) so an early exit unblocks the producer thread.
    """

    def __init__(self, make_round: Callable[[int], Any], start: int, stop: int,
                 depth: int = 1, telemetry=None):
        self._make_round = make_round
        self._next = start
        self._tel = NULL_SESSION if telemetry is None else telemetry
        self._thread: Optional[threading.Thread] = None
        if depth <= 0 or stop <= start:
            return
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._produce, args=(start, stop),
            name="pigeon-round-feeder", daemon=True)
        self._thread.start()

    def _produce(self, start: int, stop: int) -> None:
        for t in range(start, stop):
            try:
                with self._tel.span("feeder.assemble", round=t):
                    item = (t, self._make_round(t), None)
            except BaseException as e:  # noqa: BLE001 — relayed to consumer
                item = (t, None, e)
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if self._stop.is_set() or item[2] is not None:
                return

    def get(self, t: int) -> Any:
        """Payload for round ``t``.  Rounds must be consumed in the same
        ascending order they were scheduled."""
        if self._next != t:
            raise RuntimeError(f"RoundFeeder consumed out of order: "
                               f"expected t={self._next}, got t={t}")
        self._next = t + 1
        if self._thread is None:            # depth=0: synchronous fallback
            return self._make_round(t)
        got_t, payload, err = self._q.get()
        if err is not None:
            raise err
        if got_t != t:
            raise RuntimeError(f"RoundFeeder produced t={got_t}, wanted t={t}")
        return payload

    def qsize(self) -> int:
        """Assembled rounds currently buffered ahead of the consumer (the
        telemetry feeder-depth gauge); 0 when running synchronously."""
        q = getattr(self, "_q", None)
        return q.qsize() if q is not None and self._thread is not None else 0

    def close(self) -> None:
        """Stop the producer; safe to call repeatedly / after exhaustion."""
        if self._thread is None:
            return
        self._stop.set()
        try:                                # unblock a producer stuck on put()
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        self._thread = None

    def __enter__(self) -> "RoundFeeder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
