from .pipeline import (build_image_task, build_lm_task, dirichlet_relabel,
                       minibatches)
from .synthetic import (lm_batch, make_classification_data, make_markov_tokens,
                        make_templates, sample_images)

__all__ = ["build_image_task", "build_lm_task", "minibatches", "lm_batch",
           "make_classification_data", "make_markov_tokens", "make_templates",
           "sample_images"]
