"""Flash attention Pallas TPU kernel (causal, GQA, optional sliding window).

TPU adaptation of the classic GPU flash-attention blocking: instead of a
warp-level streaming softmax, the kernel tiles (block_q x d) query panels and
(block_k x d) key/value panels into VMEM and walks the key axis as the
*minor sequential grid dimension*, carrying the running (m, l, acc) softmax
state in VMEM scratch between grid steps.  Block shapes default to
(128, 128) so the q @ k^T and p @ v contractions land on MXU-aligned
(128, head_dim) tiles.  HBM traffic is Q+K+V+O only — the (S x S) score
matrix never leaves VMEM, which removes the dominant memory-roofline term of
the XLA attention path (see EXPERIMENTS.md §Perf).

Layout: q (BH, Sq, D); k, v (BHkv, Sk, D).  GQA is handled in the index
maps: query row b maps to kv row (b // H) * Hkv + (b % H) // (H // Hkv).

Validated against ``ref.mha_reference`` in interpret mode (tests/test_kernels_*).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_k: int,
                  window: int, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window > 0:
        mask = mask & (q_pos - k_pos < window)

    # skip fully-masked blocks (still executed — grid steps are sequential —
    # but the vector work is predicated out)
    block_live = jnp.logical_not(causal) | (qi * block_q + block_q - 1 >= kj * block_k)
    if window > 0:
        block_live = block_live & (kj * block_k + block_k - 1 > qi * block_q - window)

    @pl.when(block_live)
    def _step():
        q = q_ref[0].astype(jnp.float32)                    # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BHkv, Sk, D) with BH % BHkv == 0."""
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    assert bh % bhkv == 0
    groups = bh // bhkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=sk, window=window, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // groups, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q,), jnp.float32),
            _vmem((block_q,), jnp.float32),
            _vmem((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
