"""Tamper-check Pallas TPU kernel.

The Section III-C defence compares the cut-layer activations transmitted by
the next-round first clients against the validation-time reference — at LLM
scale that is R x (D_o x seq x d_model) element-wise distances per round.
The kernel streams both activation matrices through VMEM in (block_n x D)
panels and accumulates the squared-L2 distance and the reference squared
norm in scratch, emitting the single (relative-distance numerator,
denominator) pair — one pass over HBM, no intermediate difference tensor.

Layout: ref, recv (N, D); output (2,) f32 = [sum |a-b|^2, sum |a|^2].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _tamper_kernel(ref_ref, recv_ref, o_ref, acc_scr):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = ref_ref[...].astype(jnp.float32)
    b = recv_ref[...].astype(jnp.float32)
    d = a - b
    acc_scr[0] = acc_scr[0] + jnp.sum(d * d)
    acc_scr[1] = acc_scr[1] + jnp.sum(a * a)

    @pl.when(i == n - 1)
    def _finish():
        o_ref[...] = acc_scr[...]


def tamper_check_sums(ref: jnp.ndarray, recv: jnp.ndarray, *,
                      block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """ref, recv: (N, D) -> (2,) = [||ref - recv||^2, ||ref||^2]."""
    n, d = ref.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        functools.partial(_tamper_kernel),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2,), jnp.float32)],
        interpret=interpret,
    )(ref, recv)
