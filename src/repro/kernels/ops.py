"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real TPU
backends — the kernels are written for TPU (pl.pallas_call + BlockSpec VMEM
tiling) and validated here in interpret mode against the ref.py oracles.
"""
from __future__ import annotations

import math
from functools import lru_cache, partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import fused_xent as _fx
from . import tamper_check as _tc


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """Multi-head attention. q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D).
    Returns (B, Sq, H, D)."""
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, h, d = q.shape
    _, sk, hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, d)
    of = _fa.flash_attention(qf, kf, vf, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_cross_entropy(hidden, weights, labels, *, block_t: int = 256,
                        block_v: int = 512, interpret: Optional[bool] = None):
    """Mean fused softmax-xent.  hidden (..., D); labels (...,)."""
    interpret = _default_interpret() if interpret is None else interpret
    d = hidden.shape[-1]
    h2 = hidden.reshape(-1, d)
    l2 = labels.reshape(-1)
    per_tok = _fx.fused_xent(h2, weights, l2, block_t=block_t, block_v=block_v,
                             interpret=interpret)
    return jnp.mean(per_tok)


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, k, v, index, *, window: int = 0, block_k: int = 512,
                     interpret: Optional[bool] = None):
    """Single-token decode attention. q: (B, 1, H, D); k, v: (B, S, Hkv, D);
    index: scalar position of the new token.  Returns (B, 1, H, D)."""
    from . import decode_attention as _da
    interpret = _default_interpret() if interpret is None else interpret
    b, _, h, d = q.shape
    _, s, hkv, _ = k.shape
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    of = _da.decode_attention(qf, kf, vf, index, window=window,
                              block_k=block_k, interpret=interpret)
    return of.reshape(b, h, 1, d).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("n_heads", "interpret"))
def slstm_scan(pre, r, *, n_heads: int, interpret: Optional[bool] = None):
    """Fused sLSTM time scan. pre: (T, B, 4d); r: (H, dh, 4dh) -> (T, B, d)."""
    from . import slstm_scan as _ss
    interpret = _default_interpret() if interpret is None else interpret
    return _ss.slstm_scan(pre, r, n_heads=n_heads, interpret=interpret)


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n at most ``cap``.  Kernel grids require
    N % block == 0 (and shared-set sizes like D_o = 1500 are not always
    multiples of the default tile); the selection subsystem's shard-count
    clamp (``repro.selection.effective_shards``) delegates here too."""
    cap = max(1, min(cap, n))
    while n % cap:
        cap -= 1
    return cap


@partial(jax.jit, static_argnames=("fmt", "block_n", "interpret"))
def quant_roundtrip(x, fmt: str, *, block_n: int = 256,
                    interpret: Optional[bool] = None):
    """Per-row symmetric quantize->dequantize of a (N, D) message through
    the ``quant_exchange`` kernel.  Returns (dequantized (N, D) f32,
    per-row scales (N,) f32) — the message a receiver reconstructs from
    ``1 byte/element + 4 bytes/row`` on the wire."""
    from . import quant_exchange as _qx
    interpret = _default_interpret() if interpret is None else interpret
    return _qx.quant_dequant(x, fmt,
                             block_n=largest_divisor(x.shape[0], block_n),
                             interpret=interpret)


@partial(jax.jit, static_argnames=("fmt", "block_n", "interpret"))
def quant_roundtrip_stats(x, fmt: str, *, block_n: int = 256,
                          interpret: Optional[bool] = None):
    """:func:`quant_roundtrip` fused with the AP-observable message
    statistics of the *dequantized* message (``core.split.message_stats``:
    dispersion + support residual) — anomaly-scoring selection policies pay
    nothing extra under quantization.  Returns (deq, scales, stats (2,))."""
    from . import quant_exchange as _qx
    interpret = _default_interpret() if interpret is None else interpret
    return _qx.quant_dequant_stats(x, fmt,
                                   block_n=largest_divisor(x.shape[0], block_n),
                                   interpret=interpret)


@lru_cache(maxsize=None)
def _quant_exchange_fn(fmt: str):
    """Straight-through both-direction wire model for fused SPMD train steps
    (the launch layer): the forward quantizes the uplink activation message,
    the backward quantizes the downlink cut-gradient cotangent — one
    ``value_and_grad`` over the composed split model then sees exactly the
    two messages a real AP/client pair would exchange."""

    def _qdq(x):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        deq, _ = quant_roundtrip(flat, fmt)
        return deq.reshape(x.shape).astype(x.dtype)

    @jax.custom_vjp
    def exchange(x):
        return _qdq(x)

    def fwd(x):
        return _qdq(x), None

    def bwd(_, g):
        return (_qdq(g),)

    exchange.defvjp(fwd, bwd)
    return exchange


def quant_cut_exchange(x, fmt: Optional[str]):
    """Apply the quantized cut-layer wire to an activation tensor (leading
    batch axis, any trailing shape).  ``fmt=None`` is the f32 identity."""
    if fmt is None:
        return x
    from . import quant_exchange as _qx
    _qx.check_format(fmt)
    return _quant_exchange_fn(fmt)(x)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def tamper_distance(ref, recv, *, block_n: int = 256,
                    interpret: Optional[bool] = None):
    """Relative L2 distance ||ref-recv|| / ||ref|| between activation sets.
    ref/recv: (..., D) — flattened to (N, D).  The fused selection cascade's
    verify stage (``repro.selection``) maps this over the R candidate
    handoffs inside the compiled round program."""
    interpret = _default_interpret() if interpret is None else interpret
    d = ref.shape[-1]
    a = ref.reshape(-1, d)
    b = recv.reshape(-1, d)
    sums = _tc.tamper_check_sums(a, b, block_n=largest_divisor(a.shape[0], block_n),
                                 interpret=interpret)
    return jnp.sqrt(sums[0]) / jnp.maximum(jnp.sqrt(sums[1]), 1e-12)
