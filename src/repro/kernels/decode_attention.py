"""Flash-decoding Pallas TPU kernel: one new token against a long KV cache.

The decode_32k / long_500k serve rows are memory-bound on the KV-cache sweep
(and collective-bound when GSPMD all-gathers sharded caches).  This kernel
streams the cache through VMEM in (block_k x d) panels with a running
softmax carry, so per-step HBM traffic is exactly one cache read and the
(1 x S) score row never materialises.  With the cache sequence-sharded
(`--seq-shard-cache` layout) each shard runs this kernel over its local
panel and the partial (out, m, l) triples combine with one tiny psum —
the shard_map flash-decoding schedule.

Layout: q (BH, 1, D); k, v (BHkv, S, D); index = number of valid cache
positions - 1 (causal: attend to k_pos <= index), optional sliding window.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, window: int):
    kj = pl.program_id(1)
    nk = pl.num_programs(1)
    index = idx_ref[0]

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = k_pos <= index
    if window > 0:
        valid = valid & (index - k_pos < window)

    q = q_ref[0].astype(jnp.float32)                        # (1, d)
    k = k_ref[0].astype(jnp.float32)                        # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1, bk)
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     index: jnp.ndarray, *, window: int = 0,
                     scale: float | None = None, block_k: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (BH, 1, D); k, v: (BHkv, S, D); index: scalar int32.
    Returns (BH, 1, D)."""
    bh, _, d = q.shape
    bhkv, s, _ = k.shape
    assert bh % bhkv == 0
    groups = bh // bhkv
    block_k = min(block_k, s)
    assert s % block_k == 0
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               window=window)
    idx = jnp.asarray(index, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_k),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b // groups, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, j: (b // groups, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(idx, q, k, v)
