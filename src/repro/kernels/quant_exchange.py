"""Fused quantize->dequantize Pallas TPU kernel for the cut-layer exchange.

The SL wire cost is dominated by the two per-batch cut-layer messages
(activations up, cut gradients down — Table I's 2*E*B*d_c floats per client
turn).  This kernel models the compressed wire: per-row (per-sample)
symmetric quantization to int8 or fp8-e4m3 with one f32 scale per row,
immediately dequantized — the AP-side program consumes exactly the message a
real receiver would reconstruct, and the byte accounting charges
``1 byte/element + 4 bytes/row`` instead of 4 bytes/element.

Two variants share the row-block arithmetic:

  * :func:`quant_dequant` — one pass, grid over row blocks, emits the
    dequantized message (N, D) and the per-row scales (N,).
  * :func:`quant_dequant_stats` — a two-phase grid ``(2, nb)`` that
    additionally fuses the AP-observable anomaly statistics of the
    *dequantized* message (``core.split.message_stats``: dispersion +
    support residual), so anomaly-scoring selection policies pay nothing
    extra for them under quantization.  Phase 0 quantizes and accumulates
    the column sums (the batch mean); phase 1 re-reads the dequantized
    blocks and accumulates the mean-relative distances and support norms,
    finalising the (2,) stats vector at the last grid step — the
    ``tamper_check`` scratch-accumulator pattern, one level up.

Layout: x (N, D) f32; TPU note: int8/fp8 tiles want (32, 128) minimum —
``block_n`` below is the row-block size, the feature dim stays whole.
Validated in interpret mode on CPU against the ``ref.py`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8 = "int8"
FP8_E4M3 = "fp8_e4m3"
QUANT_FORMATS = (INT8, FP8_E4M3)

#: symmetric clip range per format (int8: +-127; fp8-e4m3: +-448)
QMAX = {INT8: 127.0, FP8_E4M3: 448.0}

_EPS = 1e-12


def fp8_supported() -> bool:
    """fp8-e4m3 needs a jax/ml_dtypes build exposing ``float8_e4m3fn``."""
    return hasattr(jnp, "float8_e4m3fn")


def check_format(fmt: str) -> None:
    if fmt not in QUANT_FORMATS:
        raise ValueError(f"quant format {fmt!r} must be one of {QUANT_FORMATS}")
    if fmt == FP8_E4M3 and not fp8_supported():
        raise NotImplementedError(
            "fp8_e4m3 quantization needs a jax build with jnp.float8_e4m3fn; "
            "use quant='int8' on this backend")


def _qdq_block(a: jnp.ndarray, fmt: str):
    """Per-row symmetric quantize->dequantize of one (rows, D) f32 block.
    Returns (dequantized block, per-row scales).  The round trip through the
    narrow dtype is explicit, so the dequantized values are exactly what a
    receiver reconstructs from the wire bytes."""
    qmax = jnp.float32(QMAX[fmt])
    amax = jnp.max(jnp.abs(a), axis=1)
    scale = jnp.maximum(amax, jnp.float32(_EPS)) / qmax
    s = scale[:, None]
    if fmt == INT8:
        q = jnp.clip(jnp.round(a / s), -qmax, qmax).astype(jnp.int8)
    else:
        q = jnp.clip(a / s, -qmax, qmax).astype(jnp.float8_e4m3fn)
    return q.astype(jnp.float32) * s, scale


def _quant_kernel(x_ref, deq_ref, scale_ref, *, fmt):
    a = x_ref[...].astype(jnp.float32)
    deq, scale = _qdq_block(a, fmt)
    deq_ref[...] = deq
    scale_ref[...] = scale


def quant_dequant(x: jnp.ndarray, fmt: str, *, block_n: int = 256,
                  interpret: bool = False):
    """x: (N, D) -> (dequantized (N, D) f32, scales (N,) f32)."""
    check_format(fmt)
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        functools.partial(_quant_kernel, fmt=fmt),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_n, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_n,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=interpret,
    )(x)


def _quant_stats_kernel(x_ref, deq_ref, scale_ref, stats_ref, colsum_scr,
                        acc_scr, *, fmt, n_total):
    p = pl.program_id(0)          # phase: 0 quantize+mean, 1 stats
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when((p == 0) & (i == 0))
    def _init():
        colsum_scr[...] = jnp.zeros_like(colsum_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = x_ref[...].astype(jnp.float32)
    deq, scale = _qdq_block(a, fmt)
    deq_ref[...] = deq
    scale_ref[...] = scale

    @pl.when(p == 0)
    def _accumulate_mean():
        colsum_scr[...] = colsum_scr[...] + jnp.sum(deq, axis=0, keepdims=True)

    nt = jnp.float32(n_total)

    @pl.when(p == 1)
    def _accumulate_stats():
        mu = colsum_scr[...] / nt
        dev = deq - mu
        acc_scr[0] = acc_scr[0] + jnp.sum(jnp.sqrt(jnp.sum(dev * dev, axis=1)))
        acc_scr[1] = acc_scr[1] + jnp.sum(jnp.minimum(deq, jnp.float32(0.0)) ** 2)
        acc_scr[2] = acc_scr[2] + jnp.sum(deq * deq)

    @pl.when((p == 1) & (i == nb - 1))
    def _finish():
        mu = colsum_scr[...] / nt
        mu_norm = jnp.maximum(jnp.sqrt(jnp.sum(mu * mu)), jnp.float32(_EPS))
        dispersion = (acc_scr[0] / nt) / mu_norm
        total = jnp.maximum(jnp.sqrt(acc_scr[2]), jnp.float32(_EPS))
        support = jnp.sqrt(acc_scr[1]) / total
        stats_ref[...] = jnp.stack([dispersion, support])


def quant_dequant_stats(x: jnp.ndarray, fmt: str, *, block_n: int = 256,
                        interpret: bool = False):
    """x: (N, D) -> (dequantized (N, D) f32, scales (N,) f32, stats (2,) f32)
    where stats == ``core.split.message_stats`` of the dequantized message."""
    check_format(fmt)
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        functools.partial(_quant_stats_kernel, fmt=fmt, n_total=float(n)),
        grid=(2, n // block_n),
        in_specs=[pl.BlockSpec((block_n, d), lambda p, i: (i, 0))],
        out_specs=[pl.BlockSpec((block_n, d), lambda p, i: (i, 0)),
                   pl.BlockSpec((block_n,), lambda p, i: (i,)),
                   pl.BlockSpec((2,), lambda p, i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32),
                        pltpu.VMEM((3,), jnp.float32)],
        interpret=interpret,
    )(x)
