"""Fused sLSTM time-scan Pallas TPU kernel.

§Perf hillclimb B found the sLSTM layers' dominant HBM traffic to be the
recurrent weight matrix R (and, in training, its gradient accumulator)
streamed from HBM at *every timestep* of the 4096-step scan — ~50% of the
xlstm-1.3b training bytes. The TPU-native fix is structural: keep R and the
(h, c, n, m) state resident in VMEM across the whole time loop and stream
only the per-step pre-activations.

Kernel layout: grid = (T,) sequential; R is tiled into VMEM once via a
constant index_map (Pallas keeps the block resident since the slice never
changes); the running state lives in VMEM scratch. Per-step HBM traffic
drops from (R 16 MB + x_t) to (x_t + h_t) — the K-fold `slstm_unroll`
XLA mitigation approaches this, the kernel *is* the limit case.

Stabilised exponential gating follows xLSTM [arXiv:2405.04517] exactly
(same math as models/xlstm._slstm_step); validated against it in
interpret mode by tests/test_kernels.py::test_slstm_kernel_matches_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(pre_ref, r_ref, o_ref, h_scr, c_scr, n_scr, m_scr, *,
                  n_heads: int, d_head: int):
    t = pl.program_id(0)
    d = n_heads * d_head

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    pre = pre_ref[0].astype(jnp.float32)                    # (B, 4d)
    b = pre.shape[0]
    # recurrent contribution: block-diagonal per head.  r_ref: (H, dh, 4dh)
    h_prev = h_scr[...].reshape(b, n_heads, d_head)
    rec = jax.lax.dot_general(
        h_prev.transpose(1, 0, 2), r_ref[...].astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                 # (H, B, 4dh)
    rec = rec.transpose(1, 0, 2).reshape(b, 4 * d)
    z = pre + rec
    li, lf_raw, zz, oo = jnp.split(z, 4, axis=-1)
    lf = jax.nn.log_sigmoid(lf_raw)
    m_new = jnp.maximum(lf + m_scr[...], li)
    i = jnp.exp(li - m_new)
    f = jnp.exp(lf + m_scr[...] - m_new)
    c = f * c_scr[...] + i * jnp.tanh(zz)
    n = f * n_scr[...] + i
    h = jax.nn.sigmoid(oo) * c / jnp.maximum(n, 1.0)
    c_scr[...] = c
    n_scr[...] = n
    m_scr[...] = m_new
    h_scr[...] = h
    o_ref[0] = h.astype(o_ref.dtype)


def slstm_scan(pre: jnp.ndarray, r: jnp.ndarray, *, n_heads: int,
               interpret: bool = False) -> jnp.ndarray:
    """pre: (T, B, 4d) input pre-activations; r: (H, dh, 4*dh) recurrent
    weights (gates ordered [i, f, z, o] both in ``pre`` columns and in the
    last dim of ``r`` per head).  Returns hidden states (T, B, d)."""
    t, b, d4 = pre.shape
    d = d4 // 4
    d_head = d // n_heads
    kernel = functools.partial(_slstm_kernel, n_heads=n_heads, d_head=d_head)
    return pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, b, 4 * d), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_heads, d_head, 4 * d_head), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, b, d), pre.dtype),
        scratch_shapes=[
            pltpu.VMEM((b, d), jnp.float32),   # h
            pltpu.VMEM((b, d), jnp.float32),   # c
            pltpu.VMEM((b, d), jnp.float32),   # n
            pltpu.VMEM((b, d), jnp.float32),   # m
        ],
        interpret=interpret,
    )(pre, r)
