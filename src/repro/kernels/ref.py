"""Pure-jnp oracles for every Pallas kernel (the correctness references the
shape/dtype sweep tests assert against)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def mha_reference(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BHkv, Sk, D) — GQA by head-group repetition."""
    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    groups = bh // bhkv
    k = jnp.repeat(k, groups, axis=0)
    v = jnp.repeat(v, groups, axis=0)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window > 0:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def xent_reference(hidden: jnp.ndarray, weights: jnp.ndarray,
                   labels: jnp.ndarray) -> jnp.ndarray:
    """(T, D) x (D, V), labels (T,) -> per-token loss (T,) f32."""
    logits = (hidden.astype(jnp.float32) @ weights.astype(jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return lse - picked


def tamper_sums_reference(ref: jnp.ndarray, recv: jnp.ndarray) -> jnp.ndarray:
    a = ref.astype(jnp.float32)
    b = recv.astype(jnp.float32)
    return jnp.stack([jnp.sum((a - b) ** 2), jnp.sum(a * a)])


def decode_attention_reference(q, k, v, index, window: int = 0,
                               scale: Optional[float] = None):
    """q: (BH, 1, D); k, v: (BHkv, S, D); attend to k_pos <= index."""
    bh, _, d = q.shape
    bhkv, s, _ = k.shape
    groups = bh // bhkv
    k = jnp.repeat(k, groups, axis=0)
    v = jnp.repeat(v, groups, axis=0)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    valid = pos <= index
    if window > 0:
        valid = valid & (index - pos < window)
    sc = jnp.where(valid[None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def quant_roundtrip_reference(x: jnp.ndarray, fmt: str):
    """Per-row symmetric quantize->dequantize oracle.  x: (N, D) ->
    (dequantized (N, D) f32, per-row scales (N,) f32).  int8 rounds to the
    nearest code in [-127, 127]; fp8_e4m3 routes through the narrow dtype
    itself so its rounding is the hardware's."""
    a = x.astype(jnp.float32)
    qmax = {"int8": 127.0, "fp8_e4m3": 448.0}[fmt]
    scale = jnp.maximum(jnp.max(jnp.abs(a), axis=1), 1e-12) / qmax
    s = scale[:, None]
    if fmt == "int8":
        q = jnp.clip(jnp.round(a / s), -qmax, qmax).astype(jnp.int8)
    else:
        q = jnp.clip(a / s, -qmax, qmax).astype(jnp.float8_e4m3fn)
    return q.astype(jnp.float32) * s, scale


def message_stats_reference(flat: jnp.ndarray) -> jnp.ndarray:
    """(dispersion, support_residual) of a (N, D) message — the pure-jnp
    mirror of ``core.split.message_stats`` the fused quant+stats kernel is
    checked against."""
    a = flat.astype(jnp.float32)
    mu = jnp.mean(a, axis=0, keepdims=True)
    mu_norm = jnp.maximum(jnp.linalg.norm(mu), 1e-12)
    disp = jnp.mean(jnp.linalg.norm(a - mu, axis=1)) / mu_norm
    total = jnp.maximum(jnp.linalg.norm(a), 1e-12)
    support = jnp.linalg.norm(jnp.minimum(a, 0.0)) / total
    return jnp.stack([disp, support])


def slstm_scan_reference(pre, r, n_heads: int):
    """pre: (T, B, 4d); r: (H, dh, 4dh) — mirrors models.xlstm._slstm_step."""
    t, b, d4 = pre.shape
    d = d4 // 4
    dh = d // n_heads
    h = jnp.zeros((b, d), jnp.float32)
    c = jnp.zeros((b, d), jnp.float32)
    n = jnp.zeros((b, d), jnp.float32)
    m = jnp.full((b, d), -1e30, jnp.float32)
    outs = []
    for step in range(t):
        rec = jnp.einsum("bhd,hde->bhe", h.reshape(b, n_heads, dh),
                         r.astype(jnp.float32)).reshape(b, 4 * d)
        z = pre[step].astype(jnp.float32) + rec
        li, lf_raw, zz, oo = jnp.split(z, 4, axis=-1)
        lf = jax.nn.log_sigmoid(lf_raw)
        m_new = jnp.maximum(lf + m, li)
        i = jnp.exp(li - m_new)
        f = jnp.exp(lf + m - m_new)
        c = f * c + i * jnp.tanh(zz)
        n = f * n + i
        m = m_new
        h = jax.nn.sigmoid(oo) * c / jnp.maximum(n, 1.0)
        outs.append(h)
    return jnp.stack(outs).astype(pre.dtype)
