"""Fused softmax-cross-entropy Pallas TPU kernel.

This is the compute hot-spot of the paper's selection mechanism: every
global round the AP evaluates the validation loss of all R clusters over the
shared dataset D_o — at LLM scale that is (R x D_o x seq) tokens through a
(d_model x vocab) head.  The fusion computes

    loss[t] = logsumexp_v(h[t] @ W[:, v]) - h[t] @ W[:, label[t]]

by walking vocab panels as the minor sequential grid dimension with a
running (m, l, picked) state in VMEM scratch — the (T x V) logits matrix is
never materialised in HBM (at qwen-scale vocab 152k that saves ~300 GB per
validation pass over the naive path).

Layout: hidden (T, D) f32/bf16, weights (D, V), labels (T,) int32.
Output: per-token loss (T,) f32.  Blocks: (block_t x D) x (D x block_v).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _xent_kernel(h_ref, w_ref, label_ref, o_ref, m_scr, l_scr, pick_scr, *,
                 block_t: int, block_v: int):
    vj = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(vj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        pick_scr[...] = jnp.zeros_like(pick_scr)

    h = h_ref[...].astype(jnp.float32)                       # (bt, D)
    w = w_ref[...].astype(jnp.float32)                       # (D, bv)
    logits = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (bt, bv)
    labels = label_ref[...]                                  # (bt,)
    vocab_ids = vj * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1)
    hit = vocab_ids == labels[:, None]
    pick_scr[...] = pick_scr[...] + jnp.sum(jnp.where(hit, logits, 0.0), axis=1)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    l_scr[...] = l_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(logits - m_new[:, None]), axis=1)
    m_scr[...] = m_new

    @pl.when(vj == nv - 1)
    def _finish():
        lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
        o_ref[...] = (lse - pick_scr[...]).astype(o_ref.dtype)


def fused_xent(hidden: jnp.ndarray, weights: jnp.ndarray, labels: jnp.ndarray, *,
               block_t: int = 256, block_v: int = 512,
               interpret: bool = False) -> jnp.ndarray:
    """hidden (T, D); weights (D, V); labels (T,) -> per-token loss (T,)."""
    t, d = hidden.shape
    _, v = weights.shape
    block_t = min(block_t, t)
    block_v = min(block_v, v)
    assert t % block_t == 0 and v % block_v == 0
    grid = (t // block_t, v // block_v)
    kernel = functools.partial(_xent_kernel, block_t=block_t, block_v=block_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_v), lambda i, j: (0, j)),
            pl.BlockSpec((block_t,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
            pltpu.VMEM((block_t,), jnp.float32),
        ],
        interpret=interpret,
    )(hidden, weights, labels)
