"""Mamba2 (SSD) block — chunked selective-state-space implementation.

Training/prefill uses the chunked SSD algorithm: within a chunk the
recurrence is computed as a masked quadratic form (maps onto the MXU like an
attention block), across chunks a single ``lax.scan`` carries the
``(batch, heads, head_dim, state)`` recurrent state.  Live memory is
O(chunk^2) instead of O(seq * state), which is what makes the 524k-token
long-context shape lowerable.

Decode is the O(1) recurrence: ``h = h * exp(dt*A) + dt * (B ⊗ x)``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import Params, dense_init, linear, linear_init, rmsnorm, rmsnorm_init


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def mamba2_init(key, cfg: SSMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    di, st, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj emits [z, x, B, C, dt]
    d_in_proj = 2 * di + 2 * st + h
    conv_dim = di + 2 * st
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, d_in_proj, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),   # A = -exp(A_log)
        "dt_bias": jnp.zeros((h,), dtype),
        "D": jnp.ones((h,), dtype),
        "out_norm": rmsnorm_init(di, dtype),
        "out_proj": linear_init(ks[2], di, cfg.d_model, dtype=dtype),
    }


def _depthwise_conv(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Causal depthwise conv over seq.  xBC: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + xBC.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _ssd_chunk(state, inputs, cfg: SSMConfig):
    """One SSD chunk.  state: (B, H, P, N).  inputs per-chunk arrays."""
    x, dt, Bm, Cm, A = inputs          # x:(B,Q,H,P) dt:(B,Q,H) Bm/Cm:(B,Q,N) A:(H,)
    dtA = dt * A                       # (B,Q,H) negative
    cum = jnp.cumsum(dtA, axis=1)      # (B,Q,H) running log-decay within chunk
    # intra-chunk quadratic term
    # M[t,s] = exp(cum_t - cum_s) for s<=t  (per B,H)
    diff = cum[:, :, None, :] - cum[:, None, :, :]                  # (B,Q,Q,H)
    q = x.shape[1]
    causal = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: above-diagonal entries are positive and overflow, and
    # where(causal, exp(inf), 0) produces NaN *gradients* (inf * 0)
    decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -jnp.inf))  # (B,Q,Q,H)
    cb = jnp.einsum("bqn,bsn->bqs", Cm, Bm)                         # (B,Q,Q)
    gate = decay * cb[..., None]                                    # (B,Q,Q,H)
    xdt = x * dt[..., None]                                         # (B,Q,H,P)
    y_intra = jnp.einsum("bqsh,bshp->bqhp", gate, xdt)
    # contribution from incoming state
    y_state = jnp.einsum("bqn,bhpn->bqhp", Cm, state) * jnp.exp(cum)[..., None]
    # state update
    decay_to_end = jnp.exp(cum[:, -1:, :] - cum)                    # (B,Q,H)
    dstate = jnp.einsum("bqhp,bqn,bqh->bhpn", xdt, Bm, decay_to_end)
    total_decay = jnp.exp(cum[:, -1, :])                            # (B,H)
    new_state = state * total_decay[:, :, None, None] + dstate
    return new_state, y_intra + y_state


def mamba2_forward(p: Params, cfg: SSMConfig, u: jnp.ndarray) -> jnp.ndarray:
    """u: (B, S, d_model) -> (B, S, d_model)."""
    b, s, _ = u.shape
    di, st, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = linear(p["in_proj"], u)
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * st], axis=-1)
    xBC = _depthwise_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(xBC, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H,)

    x_h = x.reshape(b, s, h, pd).astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    q = min(cfg.chunk, s)
    n_chunks = s // q
    assert n_chunks * q == s, f"chunk {q} must divide seq {s}"

    def chunker(a):
        return a.reshape(b, n_chunks, q, *a.shape[2:]).swapaxes(0, 1)

    xs = (chunker(x_h), chunker(dt), chunker(Bm), chunker(Cm))
    state0 = jnp.zeros((b, h, pd, st), jnp.float32)

    def step(state, xs_t):
        return _ssd_chunk(state, (*xs_t, A), cfg)

    _, ys = jax.lax.scan(step, state0, xs)                          # (n_chunks,B,Q,H,P)
    y = ys.swapaxes(0, 1).reshape(b, s, h, pd)
    y = y + x_h * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_ssm_cache(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, cfg.d_inner + 2 * cfg.d_state), dtype),
    }


def mamba2_decode(p: Params, cfg: SSMConfig, u: jnp.ndarray,
                  cache: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """u: (B, 1, d_model)."""
    b = u.shape[0]
    di, st, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    proj = linear(p["in_proj"], u[:, 0])                            # (B, d_in_proj)
    z, xBC_new, dt_raw = jnp.split(proj, [di, 2 * di + 2 * st], axis=-1)
    window = jnp.concatenate([cache["conv"], xBC_new[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    x, Bm, Cm = jnp.split(xBC, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    x_h = x.reshape(b, h, pd).astype(jnp.float32)
    decay = jnp.exp(dt * A)                                         # (B,H)
    dstate = jnp.einsum("bhp,bn,bh->bhpn", x_h, Bm.astype(jnp.float32), dt)
    state = cache["state"] * decay[:, :, None, None] + dstate
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y = y + x_h * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, di).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)[:, None, :]
    return out, {"state": state, "conv": window[:, 1:, :]}


def mamba2_forward_reference(p: Params, cfg: SSMConfig, u: jnp.ndarray) -> jnp.ndarray:
    """Token-by-token recurrent oracle (tests only)."""
    b, s, _ = u.shape
    cache = init_ssm_cache(b, cfg, u.dtype)
    ys = []
    for t in range(s):
        y, cache = mamba2_decode(p, cfg, u[:, t : t + 1], cache)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)
