"""Top-level Model API.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions over a params pytree:

  * ``init(key)``                          — parameter initialisation
  * ``forward(params, batch)``             — final hidden states (B, S, D)
  * ``loss(params, batch)``                — scalar LM loss (+ MoE aux)
  * ``split_params(params)``               — (client γ, AP φ) at cfg.cut_layer
  * ``client_forward(γ, batch)``           — cut-layer activations (the SL
                                             "smashed data" sent to the AP)
  * ``ap_forward(φ, acts, batch)``         — loss from cut activations
  * ``init_cache(batch_size, max_seq)``    — decode cache
  * ``decode_step(params, cache, tok, i)`` — one-token decode -> (logits, cache)

The client/AP decomposition is exactly the paper's gamma/phi split; the cut
layer activation tensor is what the attack/defence machinery in
``repro.core`` tampers with and validates.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import transformer as tfm
from .blocks import cross_entropy, embed_init, linear, rmsnorm, rmsnorm_init
from .config import ModelConfig

Pytree = Any


@dataclasses.dataclass
class StackPlan:
    kind: str
    n: int
    meta: Dict[str, Any]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    plan: List[StackPlan]
    enc_plan: Optional[List[StackPlan]] = None

    # -- construction -------------------------------------------------------
    def init(self, key) -> Pytree:
        cfg = self.cfg
        dt = tfm._dtype(cfg)
        k_emb, k_stacks, k_head, k_enc = jax.random.split(key, 4)
        stacks = tfm.build_stacks(cfg, k_stacks)
        # plan must match build order
        params: Dict[str, Any] = {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, dt),
            "stacks": tuple(s.params for s in stacks),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
            "head": {"w": embed_init(k_head, cfg.d_model, cfg.vocab, dt)},
        }
        if cfg.arch_type in ("encdec", "audio"):
            n_enc = cfg.n_enc_layers or cfg.n_layers
            enc = tfm.stack_init(k_enc, n_enc, partial(tfm._encdec_enc_init, cfg))
            params["encoder"] = {"stacks": (enc,), "norm": rmsnorm_init(cfg.d_model, dt)}
        return params

    def _stacks(self, stack_params, plan=None) -> List[tfm.BlockStack]:
        plan = plan or self.plan
        return [tfm.BlockStack(sp.kind, sp.n, p, sp.meta)
                for sp, p in zip(plan, stack_params)]

    # -- embedding & prefix handling ----------------------------------------
    def embed(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x, positions)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens] * jnp.asarray(
            jnp.sqrt(float(cfg.d_model)), x_dtype(params))
        if cfg.arch_type == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])
        return x, positions

    def encode(self, params, batch) -> jnp.ndarray:
        """Encoder pass for encdec/audio — consumes precomputed frame
        embeddings (the modality frontend stub)."""
        cfg = self.cfg
        x = batch["frames"].astype(x_dtype(params))
        enc_stack = tfm.BlockStack("enc", x.shape[0], params["encoder"]["stacks"][0])
        # scan over encoder layers
        def body(carry, layer):
            return (tfm._encdec_enc_layer(cfg, layer, carry), None)[0], None
        fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(fn, x, params["encoder"]["stacks"][0])
        return rmsnorm(params["encoder"]["norm"], x)

    # -- forward / loss ------------------------------------------------------
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full forward to final hidden states.  Returns (hidden, aux)."""
        cfg = self.cfg
        memory = self.encode(params, batch) if cfg.arch_type in ("encdec", "audio") else None
        x, positions = self.embed(params, batch)
        aux = jnp.zeros((), jnp.float32)
        for stack in self._stacks(params["stacks"]):
            x, a = tfm.run_stack(cfg, stack, x, positions, memory)
            aux = aux + a
        return rmsnorm(params["final_norm"], x), aux

    def logits(self, params, batch) -> jnp.ndarray:
        h, _ = self.forward(params, batch)
        return linear(params["head"], h)

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.arch_type == "vlm" and "patches" in batch:
            h = h[:, batch["patches"].shape[1]:, :]   # loss over text positions only
        mask = batch.get("mask")
        if cfg.loss_chunk and h.shape[1] > cfg.loss_chunk:
            lm = _chunked_xent(params["head"]["w"], h, labels, mask, cfg.loss_chunk)
        else:
            logits = linear(params["head"], h)
            lm = cross_entropy(logits, labels, mask)
        return lm + aux, {"lm_loss": lm, "aux_loss": aux}

    # -- split-learning view -------------------------------------------------
    def split_plans(self) -> Tuple[List[StackPlan], List[StackPlan], List[Tuple[int, int, int]]]:
        """Split the plan at cfg.cut_layer blocks.  Returns (client_plan,
        ap_plan, slices) where slices[i] = (stack_idx, client_n, total_n)."""
        cut = self.cfg.cut_layer
        client, ap, slices = [], [], []
        seen = 0
        for idx, sp in enumerate(self.plan):
            take = max(0, min(sp.n, cut - seen))
            if take == sp.n:
                client.append(sp)
            elif take == 0:
                ap.append(sp)
            else:
                client.append(StackPlan(sp.kind, take, _slice_meta(sp.meta, 0, take)))
                ap.append(StackPlan(sp.kind, sp.n - take, _slice_meta(sp.meta, take, sp.n)))
            slices.append((idx, take, sp.n))
            seen += sp.n
        return client, ap, slices

    def split_params(self, params) -> Tuple[Pytree, Pytree]:
        _, _, slices = self.split_plans()
        client_stacks, ap_stacks = [], []
        for (idx, take, total), sp in zip(slices, params["stacks"]):
            if take == total:
                client_stacks.append(sp)
            elif take == 0:
                ap_stacks.append(sp)
            else:
                client_stacks.append(jax.tree.map(lambda a: a[:take], sp))
                ap_stacks.append(jax.tree.map(lambda a: a[take:], sp))
        gamma = {"embed": params["embed"], "stacks": tuple(client_stacks)}
        if "encoder" in params:
            gamma["encoder"] = params["encoder"]
        phi = {"stacks": tuple(ap_stacks), "final_norm": params["final_norm"],
               "head": params["head"]}
        return gamma, phi

    def merge_params(self, gamma, phi) -> Pytree:
        _, _, slices = self.split_plans()
        stacks, ci, ai = [], 0, 0
        for idx, take, total in slices:
            if take == total:
                stacks.append(gamma["stacks"][ci]); ci += 1
            elif take == 0:
                stacks.append(phi["stacks"][ai]); ai += 1
            else:
                c, a = gamma["stacks"][ci], phi["stacks"][ai]
                stacks.append(jax.tree.map(lambda x, y: jnp.concatenate([x, y]), c, a))
                ci += 1; ai += 1
        params = {"embed": gamma["embed"], "stacks": tuple(stacks),
                  "final_norm": phi["final_norm"], "head": phi["head"]}
        if "encoder" in gamma:
            params["encoder"] = gamma["encoder"]
        return params

    def client_forward(self, gamma, batch) -> jnp.ndarray:
        """Client-side NN g(x, γ): embedding + first cut_layer blocks ->
        cut-layer activations (B, S, d_model)."""
        cfg = self.cfg
        client_plan, _, _ = self.split_plans()
        memory = None
        params_view = {"embed": gamma["embed"]}
        x, positions = self.embed(params_view, batch)
        if cfg.arch_type in ("encdec", "audio"):
            memory = self.encode(gamma, batch)
        for stack in self._stacks(gamma["stacks"], client_plan):
            x, _ = tfm.run_stack(cfg, stack, x, positions, memory)
        if memory is not None:
            # the smashed data for enc-dec includes the encoder memory
            return jnp.concatenate([x, memory], axis=1)
        return x

    def ap_forward(self, phi, acts, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        """AP-side NN h(a, φ): remaining blocks + head -> loss."""
        cfg = self.cfg
        _, ap_plan, _ = self.split_plans()
        memory = None
        if cfg.arch_type in ("encdec", "audio"):
            s_dec = batch["tokens"].shape[1]
            memory = acts[:, s_dec:, :]
            acts = acts[:, :s_dec, :]
        x = acts
        positions = jnp.arange(x.shape[1])
        aux = jnp.zeros((), jnp.float32)
        for stack in self._stacks(phi["stacks"], ap_plan):
            x, a = tfm.run_stack(cfg, stack, x, positions, memory)
            aux = aux + a
        h = rmsnorm(phi["final_norm"], x)
        labels = batch["labels"]
        if cfg.arch_type == "vlm" and "patches" in batch:
            h = h[:, batch["patches"].shape[1]:, :]
        mask = batch.get("mask")
        if cfg.loss_chunk and h.shape[1] > cfg.loss_chunk:
            lm = _chunked_xent(phi["head"]["w"], h, labels, mask, cfg.loss_chunk)
        else:
            lm = cross_entropy(linear(phi["head"], h), labels, mask)
        return lm + aux, {"lm_loss": lm, "aux_loss": aux}

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch_size: int, max_seq: int, dtype=None) -> Tuple:
        cfg = self.cfg
        dtype = dtype or tfm._dtype(cfg)
        caches = []
        for sp in self.plan:
            stack = tfm.BlockStack(sp.kind, sp.n, None, sp.meta)
            caches.append(tfm.init_stack_cache(cfg, stack, batch_size, max_seq, dtype))
        return tuple(caches)

    def decode_step(self, params, cache, tokens, index, memory=None):
        """tokens: (B, 1) int32; index: scalar position.  Returns
        (logits (B, 1, V), new_cache)."""
        cfg = self.cfg
        x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(x_dtype(params))
        new_caches = []
        for stack, c in zip(self._stacks(params["stacks"]), cache):
            x, nc = tfm.decode_stack(cfg, stack, x, c, index, memory)
            new_caches.append(nc)
        h = rmsnorm(params["final_norm"], x)
        return linear(params["head"], h), tuple(new_caches)


def x_dtype(params) -> jnp.dtype:
    return params["embed"].dtype if "embed" in params else jnp.float32


def _slice_meta(meta: Dict[str, Any], lo: int, hi: int) -> Dict[str, Any]:
    return {k: v[lo:hi] for k, v in meta.items()}


def _chunked_xent(head_w, h, labels, mask, chunk):
    """Scan over sequence chunks so the full (B, S, V) logits tensor is never
    live — the memory-side optimisation recorded in EXPERIMENTS.md §Perf."""
    from .attention import largest_divisor_chunk
    b, s, d = h.shape
    chunk = largest_divisor_chunk(s, chunk)
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(b, n, chunk).swapaxes(0, 1)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mc = mask.reshape(b, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        hi, li, mi = xs
        logits = (hi @ head_w).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        sum_loss, sum_mask = carry
        return (sum_loss + jnp.sum((lse - picked) * mi), sum_mask + jnp.sum(mi)), None

    (total, denom), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)), (hc, lc, mc))
    return total / jnp.maximum(denom, 1.0)


def build_plan(cfg: ModelConfig) -> List[StackPlan]:
    """Static stack layout — MUST mirror tfm.build_stacks ordering, but
    without allocating any parameters (the dry-run never materialises the
    full-size models)."""
    at = cfg.arch_type
    plan: List[StackPlan] = []
    if at in ("dense", "vlm"):
        plan.append(StackPlan("attn_mlp", cfg.n_layers, {"window": tfm._layer_windows(cfg)}))
    elif at == "moe":
        if cfg.first_dense:
            plan.append(StackPlan("dense_mlp", cfg.first_dense, {}))
        plan.append(StackPlan("moe", cfg.n_layers - cfg.first_dense, {}))
    elif at == "ssm":
        if cfg.slstm_every:
            remaining = cfg.n_layers
            while remaining > 0:
                n_m = min(cfg.slstm_every - 1, remaining)
                if n_m > 0:
                    plan.append(StackPlan("mlstm", n_m, {}))
                    remaining -= n_m
                if remaining > 0:
                    plan.append(StackPlan("slstm", 1, {}))
                    remaining -= 1
        else:
            plan.append(StackPlan("mamba", cfg.n_layers, {}))
    elif at == "hybrid":
        remaining = cfg.n_layers
        period = cfg.attn_every or cfg.n_layers
        while remaining > 0:
            n_m = min(period, remaining)
            plan.append(StackPlan("mamba", n_m, {}))
            remaining -= n_m
            if remaining > 0:
                plan.append(StackPlan("shared_attn", 1, {}))
    elif at in ("encdec", "audio"):
        plan.append(StackPlan("dec_cross", cfg.n_layers, {}))
    else:
        raise ValueError(at)
    return plan


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, plan=build_plan(cfg))
