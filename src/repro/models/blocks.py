"""Common neural-net building blocks (pure JAX, dict-based params).

Every block follows the ``init(key, ...) -> params`` / ``apply(params, x, ...)``
convention.  Params are plain nested dicts of ``jnp.ndarray`` so that they can
be sliced for the split-learning cut layer, stacked for ``lax.scan`` and
sharded with ``NamedSharding`` without any framework dependency.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal (fan-in) initialisation for a dense kernel."""
    if scale is None:
        scale = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# dense / norm primitives
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32) -> Params:
    p = {"w": dense_init(key, d_in, d_out, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU and plain GeLU variants)
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff, dtype=dtype),
        "up": linear_init(k2, d_model, d_ff, dtype=dtype),
        "down": linear_init(k3, d_ff, d_model, dtype=dtype),
    }


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "up": linear_init(k1, d_model, d_ff, bias=True, dtype=dtype),
        "down": linear_init(k2, d_ff, d_model, bias=True, dtype=dtype),
    }


def gelu_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.gelu(linear(p["up"], x)))


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotate pairs of channels.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray, mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy (float32 accumulation)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    losses = lse - picked
    if mask is not None:
        return jnp.sum(losses * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(losses)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
