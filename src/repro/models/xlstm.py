"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, recurrent scan).

The mLSTM training path uses the same chunking strategy as the Mamba2 SSD
block: within a chunk the stabilised exponential-gating recurrence is
computed as a masked quadratic form, across chunks a ``lax.scan`` carries the
``(C, n, m)`` matrix-memory state (stored log-stabilised as ``C_hat =
C * exp(-m)``).  Decode is the O(1) recurrence from the xLSTM paper
[arXiv:2405.04517].
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .blocks import Params, linear, linear_init, rmsnorm, rmsnorm_init


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int = 4
    proj_factor: int = 2
    chunk: int = 256
    # dtype of the (C, n) matrix-memory carries and the big gated einsums;
    # exponents/stabilisers always stay f32.  bf16 halves the dominant
    # memory-roofline term of the 48-layer model (§Perf hillclimb B).
    state_dtype: str = "float32"
    # unroll K timesteps inside each sLSTM scan body: the recurrent weight
    # read and its gradient accumulation amortise K-fold (§Perf hillclimb B
    # iteration 2 — the recurrent weight traffic dominates the sLSTM layers).
    slstm_unroll: int = 1

    @property
    def d_inner(self) -> int:
        return self.proj_factor * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    di = cfg.d_inner
    return {
        "up": linear_init(ks[0], cfg.d_model, 2 * di, dtype=dtype),      # [x_inner, z gate]
        "wq": linear_init(ks[1], di, di, dtype=dtype),
        "wk": linear_init(ks[2], di, di, dtype=dtype),
        "wv": linear_init(ks[3], di, di, dtype=dtype),
        "w_if": linear_init(ks[4], di, 2 * cfg.n_heads, bias=True, dtype=dtype),  # gates
        "out_norm": rmsnorm_init(di, dtype),
        "down": linear_init(ks[5], di, cfg.d_model, dtype=dtype),
    }


def _mlstm_chunk(carry, inputs, scale: float, state_dtype=jnp.float32):
    """carry: (C_hat (B,H,D,D), n_hat (B,H,D), m (B,H)).

    Exponents and stabilisers stay f32; the matrix-memory carries and the
    big (B,Q,Q,H)/(B,H,D,D) einsum operands run in ``state_dtype``."""
    C_in, n_in, m_in = carry
    q, k, v, lf, li = inputs          # q,k,v: (B,Q,H,D); lf,li: (B,Q,H)
    qn = q.shape[1]
    Lf = jnp.cumsum(lf, axis=1)                                   # (B,Q,H)
    # intra-chunk log weights D[t,s] = Lf_t - Lf_s + li_s  (s <= t)
    dmat = Lf[:, :, None, :] - Lf[:, None, :, :] + li[:, None, :, :]
    causal = jnp.tril(jnp.ones((qn, qn), bool))[None, :, :, None]
    dmat = jnp.where(causal, dmat, -jnp.inf)
    a = m_in[:, None, :] + Lf                                     # (B,Q,H) inter log-scale
    m_t = jnp.maximum(a, jnp.max(dmat, axis=2))                   # (B,Q,H)
    w = jnp.exp(dmat - m_t[:, :, None, :])                        # (B,Q,Q,H)
    qs = q.astype(state_dtype)
    ks = k.astype(state_dtype)
    vs = v.astype(state_dtype)
    qk = jnp.einsum("bqhd,bshd->bqsh", qs, ks,
                    preferred_element_type=jnp.float32) * scale
    gated = (w * qk).astype(state_dtype)
    intra = jnp.einsum("bqsh,bshd->bqhd", gated, vs,
                       preferred_element_type=jnp.float32)
    inter_scale = jnp.exp(a - m_t)                                # (B,Q,H)
    inter = jnp.einsum("bqhd,bhde->bqhe", qs, C_in,
                       preferred_element_type=jnp.float32) * inter_scale[..., None]
    num = intra + inter
    denom_intra = jnp.sum(gated.astype(jnp.float32), axis=2)      # (B,Q,H)
    denom_inter = jnp.einsum("bqhd,bhd->bqh", qs, n_in,
                             preferred_element_type=jnp.float32) * inter_scale
    denom = jnp.maximum(jnp.abs(denom_intra + denom_inter), jnp.exp(-m_t))
    h = num / denom[..., None]
    # chunk-end state update
    end_w = Lf[:, -1:, :] - Lf + li                               # (B,Q,H)
    m_out = jnp.maximum(m_in + Lf[:, -1, :], jnp.max(end_w, axis=1))
    kv_w = jnp.exp(end_w - m_out[:, None, :]).astype(state_dtype)  # (B,Q,H)
    decay_out = jnp.exp(m_in + Lf[:, -1, :] - m_out)
    C_out = (C_in.astype(jnp.float32) * decay_out[..., None, None]
             + jnp.einsum("bqh,bqhd,bqhe->bhde", kv_w, ks * scale, vs,
                          preferred_element_type=jnp.float32)).astype(state_dtype)
    n_out = (n_in.astype(jnp.float32) * decay_out[..., None]
             + jnp.einsum("bqh,bqhd->bhd", kv_w, ks * scale,
                          preferred_element_type=jnp.float32)).astype(state_dtype)
    return (C_out, n_out, m_out), h


def mlstm_forward(p: Params, cfg: XLSTMConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    di, h, pd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    up = linear(p["up"], x)
    xi, z = jnp.split(up, 2, axis=-1)
    q = linear(p["wq"], xi).reshape(b, s, h, pd).astype(jnp.float32)
    k = linear(p["wk"], xi).reshape(b, s, h, pd).astype(jnp.float32)
    v = linear(p["wv"], xi).reshape(b, s, h, pd).astype(jnp.float32)
    gates = linear(p["w_if"], xi).astype(jnp.float32)             # (B,S,2H)
    li, lf_raw = jnp.split(gates, 2, axis=-1)
    lf = jax.nn.log_sigmoid(lf_raw)
    scale = 1.0 / math.sqrt(pd)

    qn = min(cfg.chunk, s)
    n_chunks = s // qn
    assert n_chunks * qn == s
    sdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]

    def chunker(arr):
        return arr.reshape(b, n_chunks, qn, *arr.shape[2:]).swapaxes(0, 1)

    xs = tuple(map(chunker, (q, k, v, lf, li)))
    carry0 = (jnp.zeros((b, h, pd, pd), sdt),
              jnp.zeros((b, h, pd), sdt),
              jnp.full((b, h), -jnp.inf, jnp.float32))
    _, hs = jax.lax.scan(lambda c, i: _mlstm_chunk(c, i, scale, sdt), carry0, xs)
    out = hs.swapaxes(0, 1).reshape(b, s, di).astype(x.dtype)
    out = rmsnorm(p["out_norm"], out) * jax.nn.silu(z)
    return linear(p["down"], out)


def init_mlstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    h, pd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, h, pd, pd), jnp.float32),
        "n": jnp.zeros((batch, h, pd), jnp.float32),
        "m": jnp.full((batch, h), -jnp.inf, jnp.float32),
    }


def mlstm_decode(p: Params, cfg: XLSTMConfig, x: jnp.ndarray,
                 cache: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b = x.shape[0]
    di, hh, pd = cfg.d_inner, cfg.n_heads, cfg.head_dim
    up = linear(p["up"], x[:, 0])
    xi, z = jnp.split(up, 2, axis=-1)
    q = linear(p["wq"], xi).reshape(b, hh, pd).astype(jnp.float32)
    k = linear(p["wk"], xi).reshape(b, hh, pd).astype(jnp.float32)
    v = linear(p["wv"], xi).reshape(b, hh, pd).astype(jnp.float32)
    gates = linear(p["w_if"], xi).astype(jnp.float32)
    li, lf_raw = jnp.split(gates, 2, axis=-1)                     # (B,H)
    lf = jax.nn.log_sigmoid(lf_raw)
    scale = 1.0 / math.sqrt(pd)
    m_new = jnp.maximum(cache["m"] + lf, li)
    decay = jnp.exp(cache["m"] + lf - m_new)
    inject = jnp.exp(li - m_new)
    C = cache["C"] * decay[..., None, None] + inject[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k * scale, v)
    n = cache["n"] * decay[..., None] + inject[..., None] * (k * scale)
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)), jnp.exp(-m_new))
    hval = (num / den[..., None]).reshape(b, di).astype(x.dtype)
    out = rmsnorm(p["out_norm"], hval) * jax.nn.silu(z)
    y = linear(p["down"], out)[:, None, :]
    return y, {"C": C, "n": n, "m": m_new}


def mlstm_forward_reference(p: Params, cfg: XLSTMConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Step-by-step recurrent oracle (tests only)."""
    b, s, _ = x.shape
    cache = init_mlstm_cache(b, cfg, x.dtype)
    ys = []
    for t in range(s):
        y, cache = mlstm_decode(p, cfg, x[:, t : t + 1], cache)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, per-head recurrent weights)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "w_in": linear_init(ks[0], d, 4 * d, bias=True, dtype=dtype),    # i,f,z,o pre-acts
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh)) / math.sqrt(dh)).astype(dtype),
        "out_norm": rmsnorm_init(d, dtype),
        "down": linear_init(ks[2], d, cfg.d_model, dtype=dtype),
    }


def init_slstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }


def _slstm_step(p: Params, cfg: XLSTMConfig, pre_x: jnp.ndarray, state):
    """pre_x: (B, 4d) input pre-activations for one step."""
    b = pre_x.shape[0]
    d, hh = cfg.d_model, cfg.n_heads
    dh = d // hh
    h_prev = state["h"].reshape(b, hh, dh).astype(jnp.float32)
    rec = jnp.einsum("bhd,hde->bhe", h_prev, p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    pre = pre_x.astype(jnp.float32) + rec
    li, lf_raw, zz, oo = jnp.split(pre, 4, axis=-1)
    lf = jax.nn.log_sigmoid(lf_raw)
    m_new = jnp.maximum(lf + state["m"], li)
    i = jnp.exp(li - m_new)
    f = jnp.exp(lf + state["m"] - m_new)
    c = f * state["c"] + i * jnp.tanh(zz)
    n = f * state["n"] + i
    h_new = jax.nn.sigmoid(oo) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm_forward(p: Params, cfg: XLSTMConfig, x: jnp.ndarray) -> jnp.ndarray:
    b, s, _ = x.shape
    pre = linear(p["w_in"], x)                                    # (B,S,4d)
    k = max(1, cfg.slstm_unroll)
    while s % k:
        k -= 1

    if k == 1:
        def step(state, pre_t):
            new = _slstm_step(p, cfg, pre_t, state)
            return new, new["h"]
        state0 = init_slstm_cache(b, cfg, x.dtype)
        _, hs = jax.lax.scan(step, state0, pre.swapaxes(0, 1))
        out = hs.swapaxes(0, 1).astype(x.dtype)
    else:
        # K steps unrolled per scan body: the recurrent weight matmul reads
        # p["r"] once per body (loop-invariant), its gradient accumulates
        # once per body — K-fold less HBM traffic than the per-step scan.
        pre_c = pre.reshape(b, s // k, k, -1).swapaxes(0, 1)      # (S/K,B,K,4d)

        def block(state, pre_blk):
            hs_blk = []
            for i in range(k):
                state = _slstm_step(p, cfg, pre_blk[:, i], state)
                hs_blk.append(state["h"])
            return state, jnp.stack(hs_blk, axis=1)               # (B,K,d)

        state0 = init_slstm_cache(b, cfg, x.dtype)
        _, hs = jax.lax.scan(block, state0, pre_c)                # (S/K,B,K,d)
        out = hs.swapaxes(0, 1).reshape(b, s, -1).astype(x.dtype)
    out = rmsnorm(p["out_norm"], out)
    return linear(p["down"], out)


def slstm_decode(p: Params, cfg: XLSTMConfig, x: jnp.ndarray,
                 cache: Dict[str, jnp.ndarray]) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    pre = linear(p["w_in"], x[:, 0])
    new = _slstm_step(p, cfg, pre, cache)
    out = rmsnorm(p["out_norm"], new["h"].astype(x.dtype))
    return linear(p["down"], out)[:, None, :], new
