"""Mixture-of-Experts layer with top-k routing and capacity-based dispatch.

Design notes (TPU adaptation):
  * Dispatch is scatter-based: tokens are placed into a static
    ``(n_experts, capacity, d_model)`` buffer at ``(expert, slot)`` computed
    from a per-expert running count.  This keeps the dispatch memory
    O(E*C*D + T*D) instead of the O(T*E*C) one-hot formulation, and the
    expert compute is a single grouped einsum so the MXU sees clean
    ``(E, C, D) x (E, D, F)`` matmuls.  FLOPs therefore scale with
    ``T * top_k`` (active experts), which keeps roofline accounting honest.
  * Experts shard over the ``model`` mesh axis (expert parallelism); the
    scatter/gather between token-sharded and expert-sharded layouts lowers to
    the all-to-all-style collectives the paper family of systems relies on.
  * Tokens beyond capacity are dropped (contribute zero), matching the
    standard capacity-factor formulation.  Tests use a generous capacity so
    the layer is exact vs. the loop-over-experts reference.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import Params, dense_init, linear, linear_init


class MoEConfig(NamedTuple):
    d_model: int
    d_expert: int            # per-expert FFN hidden size
    n_experts: int           # routed experts
    top_k: int
    n_shared: int = 0        # always-on shared experts (DeepSeek style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # emit mesh sharding constraints (experts over "model", capacity over
    # "data") — requires an ambient mesh; set only by the launch layer.
    shard: bool = False
    # shard-local dispatch: tokens reshaped to (data_shards, T_local) so the
    # capacity scatter/gather is local per data shard and only the expert
    # einsum communicates (the all-to-all pattern).  0 => global dispatch.
    shard_groups: int = 0


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_expert
    p = {
        "router": dense_init(kr, d, e, dtype),
        "gate": (jax.random.truncated_normal(kg, -2, 2, (e, d, f)) / math.sqrt(d)).astype(dtype),
        "up": (jax.random.truncated_normal(ku, -2, 2, (e, d, f)) / math.sqrt(d)).astype(dtype),
        "down": (jax.random.truncated_normal(kd, -2, 2, (e, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared > 0:
        from .blocks import swiglu_init
        p["shared"] = swiglu_init(ks, d, cfg.n_shared * f, dtype)
    return p


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _sharded_identity(x, spec):
    """Identity whose sharding constraint binds BOTH the forward value and
    the cotangent.  ``with_sharding_constraint`` alone constrains only the
    primal; the MoE dispatch backward then loses its layout and GSPMD
    all-gathers the full routed-token tensor (EXPERIMENTS.md §Perf,
    composition diagnosis)."""
    return jax.lax.with_sharding_constraint(x, spec)


def _si_fwd(x, spec):
    return jax.lax.with_sharding_constraint(x, spec), None


def _si_bwd(spec, _, g):
    return (jax.lax.with_sharding_constraint(g, spec),)


_sharded_identity.defvjp(_si_fwd, _si_bwd)


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def route(p: Params, cfg: MoEConfig, x_flat: jnp.ndarray):
    """Returns (weights (T,k), ids (T,k), aux_loss)."""
    logits = (x_flat @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)               # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    pe = probs.mean(axis=0)                                      # (E,)
    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)
    fe = onehot.sum(axis=(0, 1)) / x_flat.shape[0]               # frac tokens per expert
    aux = cfg.n_experts * jnp.sum(fe * pe) * cfg.router_aux_weight
    return weights.astype(x_flat.dtype), ids, aux


def moe_forward(p: Params, cfg: MoEConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    if cfg.shard_groups and (b * s) % cfg.shard_groups == 0 and b * s >= cfg.shard_groups * cfg.n_experts:
        return _moe_forward_local_dispatch(p, cfg, x)
    x_flat = x.reshape(b * s, d)
    t = b * s
    weights, ids, aux = route(p, cfg, x_flat)
    cap = capacity(t, cfg)

    # slot assignment: position of (token, k) within its expert's queue
    flat_ids = ids.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat_ids, cfg.n_experts, dtype=jnp.int32)   # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)             # (T*k, E)
    slot = jnp.take_along_axis(pos_in_expert, flat_ids[:, None], axis=1)[:, 0]  # (T*k,)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap - 1)

    # scatter tokens into (E, C, D)
    src = jnp.repeat(x_flat, cfg.top_k, axis=0)                  # (T*k, D)
    src = src * keep[:, None].astype(src.dtype)
    buf = jnp.zeros((cfg.n_experts, cap, d), x.dtype)
    buf = buf.at[flat_ids, slot_c].add(src)
    if cfg.shard:
        # Without this constraint GSPMD keeps the capacity dim replicated
        # across the data axis: every data shard runs ALL experts' full
        # capacity (16x overcompute, see EXPERIMENTS.md §Perf).  Forcing
        # (experts x capacity) over (model x data) turns the dispatch into
        # the all-to-all the MoE literature expects.
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(buf, P("model", "data", None))

    # grouped expert SwiGLU
    g = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])           # (E, C, D)
    if cfg.shard:
        from jax.sharding import PartitionSpec as P
        out_buf = jax.lax.with_sharding_constraint(out_buf, P("model", "data", None))

    # gather back and combine with routing weights
    gathered = out_buf[flat_ids, slot_c]                         # (T*k, D)
    gathered = gathered * keep[:, None].astype(gathered.dtype)
    gathered = gathered.reshape(t, cfg.top_k, d)
    out = jnp.einsum("tkd,tk->td", gathered, weights.astype(gathered.dtype))

    if "shared" in p:
        from .blocks import swiglu
        out = out + swiglu(p["shared"], x_flat)
    return out.reshape(b, s, d), aux


def _moe_forward_local_dispatch(p: Params, cfg: MoEConfig, x: jnp.ndarray
                                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shard-local dispatch (§Perf hillclimb A, iteration 2).

    Tokens are reshaped to (G, T_loc, D) with G = the data-axis size, so the
    slot cumsum, the capacity scatter and the combine gather are *local to
    each data shard* (a vmapped scatter over a batch-aligned sharded dim
    never crosses shards).  Only the grouped expert einsum reshards — the
    all-to-all the MoE literature expects — instead of the global scatter of
    the naive formulation, which GSPMD lowers to full replication."""
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    g = cfg.shard_groups
    t = b * s
    t_loc = t // g
    x_flat = x.reshape(t, d)
    weights, ids, aux = route(p, cfg, x_flat)               # (T,k) global route
    cap = capacity(t_loc, cfg)

    xg = x_flat.reshape(g, t_loc, d)
    idsg = ids.reshape(g, t_loc * cfg.top_k)
    wg = weights.reshape(g, t_loc, cfg.top_k)
    if cfg.shard:
        xg = jax.lax.with_sharding_constraint(xg, P("data", None, None))
        idsg = jax.lax.with_sharding_constraint(idsg, P("data", None))

    # local slot assignment per shard row
    onehot = jax.nn.one_hot(idsg, cfg.n_experts, dtype=jnp.int32)   # (G, Tk, E)
    pos = jnp.cumsum(onehot, axis=1) - 1
    slot = jnp.take_along_axis(pos, idsg[..., None], axis=2)[..., 0]  # (G, Tk)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap - 1)

    src = jnp.repeat(xg, cfg.top_k, axis=1)                 # (G, Tk, D)
    src = src * keep[..., None].astype(src.dtype)
    if cfg.shard:
        src = _sharded_identity(src, P("data", None, None))

    def scatter_one(buf, f_ids, f_slot, f_src):
        return buf.at[f_ids, f_slot].add(f_src)

    buf0 = jnp.zeros((g, cfg.n_experts, cap, d), x.dtype)
    buf = jax.vmap(scatter_one)(buf0, idsg, slot_c, src)    # (G, E, C, D)
    if cfg.shard:
        buf = _sharded_identity(buf, P("data", "model", None, None))

    gg = jnp.einsum("gecd,edf->gecf", buf, p["gate"])
    uu = jnp.einsum("gecd,edf->gecf", buf, p["up"])
    hh = jax.nn.silu(gg) * uu
    out_buf = jnp.einsum("gecf,efd->gecd", hh, p["down"])
    if cfg.shard:
        out_buf = _sharded_identity(out_buf, P("data", None, None, None))

    def gather_one(f_buf, f_ids, f_slot):
        return f_buf[f_ids, f_slot]

    gathered = jax.vmap(gather_one)(out_buf, idsg, slot_c)  # (G, Tk, D)
    if cfg.shard:
        gathered = _sharded_identity(gathered, P("data", None, None))
    gathered = gathered * keep[..., None].astype(gathered.dtype)
    gathered = gathered.reshape(g, t_loc, cfg.top_k, d)
    out = jnp.einsum("gtkd,gtk->gtd", gathered, wg.astype(gathered.dtype))
    out = out.reshape(t, d)
    if "shared" in p:
        from .blocks import swiglu
        out = out + swiglu(p["shared"], x_flat)
    return out.reshape(b, s, d), aux


def moe_forward_reference(p: Params, cfg: MoEConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact loop-over-experts oracle (E-times overcompute — tests only)."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    weights, ids, aux = route(p, cfg, x_flat)
    out = jnp.zeros_like(x_flat)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x_flat @ p["gate"][e]) * (x_flat @ p["up"][e])
        y_e = h @ p["down"][e]                                   # (T, D)
        w_e = jnp.sum(jnp.where(ids == e, weights, 0.0), axis=1)  # (T,)
        out = out + y_e * w_e[:, None].astype(y_e.dtype)
    if "shared" in p:
        from .blocks import swiglu
        out = out + swiglu(p["shared"], x_flat)
    return out.reshape(b, s, d), aux
