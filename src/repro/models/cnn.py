"""The paper's exact simulation models (Section V-A).

MNIST:    conv(1->2, 5x5, pad 2) - pool - conv(2->4, 5x5, pad 2) - pool -
          FC 32 (cut layer) - FC 10.
CIFAR-10: conv(3->32, 3x3) - pool - conv(32->64, 3x3) - pool -
          conv(64->128, 3x3) - pool - FC 256 (cut layer) - FC 128 - FC 64 - FC 10.

The cut layer is the first fully-connected layer, exactly as described: the
client-side NN ends at the cut-layer output (d_c = 32 / 256), the AP-side NN
consumes it.  2x2 max-pooling after each conv keeps the FC sizes manageable
(the paper does not spell out pooling; this is the standard choice).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .blocks import Params, cross_entropy, dense_init


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    in_channels: int
    conv_channels: Tuple[int, ...]
    kernel: int
    padding: int
    fc_sizes: Tuple[int, ...]         # first entry is the cut layer width d_c
    n_classes: int = 10

    @property
    def d_cut(self) -> int:
        return self.fc_sizes[0]

    @property
    def flat_dim(self) -> int:
        s = self.image_size
        for _ in self.conv_channels:
            s = s // 2
        return s * s * self.conv_channels[-1]


MNIST_CNN = CNNConfig(name="mnist_cnn", image_size=28, in_channels=1,
                      conv_channels=(2, 4), kernel=5, padding=2,
                      fc_sizes=(32,))
CIFAR_CNN = CNNConfig(name="cifar_cnn", image_size=32, in_channels=3,
                      conv_channels=(32, 64, 128), kernel=3, padding=1,
                      fc_sizes=(256, 128, 64))


def _conv_init(key, k: int, c_in: int, c_out: int) -> Params:
    w = jax.random.truncated_normal(key, -2, 2, (k, k, c_in, c_out)) / math.sqrt(k * k * c_in)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((c_out,), jnp.float32)}


def _conv(p: Params, x: jnp.ndarray, padding: int) -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_init(key, cfg: CNNConfig) -> Tuple[Params, Params]:
    """Returns (gamma, phi): client-side and AP-side parameters."""
    n_conv = len(cfg.conv_channels)
    keys = jax.random.split(key, n_conv + len(cfg.fc_sizes) + 1)
    convs = []
    c_in = cfg.in_channels
    for i, c_out in enumerate(cfg.conv_channels):
        convs.append(_conv_init(keys[i], cfg.kernel, c_in, c_out))
        c_in = c_out
    cut_fc = {"w": dense_init(keys[n_conv], cfg.flat_dim, cfg.d_cut),
              "b": jnp.zeros((cfg.d_cut,), jnp.float32)}
    gamma = {"convs": tuple(convs), "cut_fc": cut_fc}

    fcs = []
    d_in = cfg.d_cut
    for j, d_out in enumerate(tuple(cfg.fc_sizes[1:]) + (cfg.n_classes,)):
        fcs.append({"w": dense_init(keys[n_conv + 1 + j], d_in, d_out),
                    "b": jnp.zeros((d_out,), jnp.float32)})
        d_in = d_out
    phi = {"fcs": tuple(fcs)}
    return gamma, phi


def cnn_client_forward(gamma: Params, cfg: CNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, W, C) -> cut-layer activations (B, d_c)."""
    for p in gamma["convs"]:
        x = _maxpool2(jax.nn.relu(_conv(p, x, cfg.padding)))
    x = x.reshape(x.shape[0], -1)
    return jax.nn.relu(x @ gamma["cut_fc"]["w"] + gamma["cut_fc"]["b"])


def cnn_ap_forward(phi: Params, cfg: CNNConfig, acts: jnp.ndarray) -> jnp.ndarray:
    """Cut activations -> logits (B, n_classes)."""
    x = acts
    n = len(phi["fcs"])
    for i, p in enumerate(phi["fcs"]):
        x = x @ p["w"] + p["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def cnn_predict(gamma: Params, phi: Params, cfg: CNNConfig, x: jnp.ndarray) -> jnp.ndarray:
    return cnn_ap_forward(phi, cfg, cnn_client_forward(gamma, cfg, x))


def cnn_loss(gamma: Params, phi: Params, cfg: CNNConfig, x: jnp.ndarray,
             y: jnp.ndarray) -> jnp.ndarray:
    return cross_entropy(cnn_predict(gamma, phi, cfg, x), y)
