from .config import ModelConfig, reduce_config
from .model import Model, build_model, build_plan
from .cnn import CNNConfig, MNIST_CNN, CIFAR_CNN

__all__ = ["ModelConfig", "reduce_config", "Model", "build_model", "build_plan",
           "CNNConfig", "MNIST_CNN", "CIFAR_CNN"]
