"""Decoder/encoder assembly for every architecture family.

The model is organised as an ordered list of homogeneous ``BlockStack``s.
Layers inside a stack are stacked on a leading axis and executed with
``jax.lax.scan`` (keeps the HLO small enough to AOT-compile 48-layer models
on one CPU core).  Heterogeneous architectures (gemma3 local:global, zamba2
mamba+shared-attn, xLSTM mLSTM/sLSTM) are expressed as per-layer metadata
inside a stack or as multiple stacks.

The split-learning cut is a first-class operation: ``split_params`` divides
a model into the client side (embedding + first ``cut_layer`` blocks) and the
AP side (remaining blocks + final norm + LM head), exactly the gamma/phi
decomposition of the paper.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .attention import AttnConfig, MLAConfig
from .blocks import (Params, cross_entropy, embed_init, linear, linear_init,
                     rmsnorm, rmsnorm_init, swiglu, swiglu_init)
from .config import ModelConfig
from .moe import MoEConfig
from .ssm import SSMConfig
from .xlstm import XLSTMConfig

Pytree = Any


def _dtype(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[cfg.dtype]


def attn_cfg(cfg: ModelConfig, window: int = -1) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        sliding_window=cfg.sliding_window if window < 0 else window,
        q_chunk=cfg.q_chunk)


def mla_cfg(cfg: ModelConfig) -> MLAConfig:
    return MLAConfig(d_model=cfg.d_model, n_heads=cfg.n_heads,
                     head_dim=cfg.resolved_head_dim, kv_lora_rank=cfg.kv_lora_rank,
                     rope_dim=cfg.rope_dim, rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk)


def moe_cfg(cfg: ModelConfig) -> MoEConfig:
    shard = "moe_shard" in cfg.optimizations
    return MoEConfig(d_model=cfg.d_model, d_expert=cfg.d_expert, n_experts=cfg.n_experts,
                     top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
                     capacity_factor=cfg.capacity_factor,
                     shard=shard, shard_groups=16 if shard else 0)


def ssm_cfg(cfg: ModelConfig) -> SSMConfig:
    return SSMConfig(d_model=cfg.d_model, d_state=cfg.ssm_state, chunk=cfg.ssm_chunk)


def xlstm_cfg(cfg: ModelConfig) -> XLSTMConfig:
    return XLSTMConfig(d_model=cfg.d_model, n_heads=cfg.n_heads, chunk=cfg.ssm_chunk,
                       state_dtype=("bfloat16" if "mlstm_bf16_state" in cfg.optimizations
                                    else "float32"),
                       slstm_unroll=16 if "slstm_unroll" in cfg.optimizations else 1)


# ---------------------------------------------------------------------------
# BlockStack
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BlockStack:
    kind: str                 # attn_mlp | mla_moe | moe | mamba | shared_attn | mlstm | slstm
    n: int                    # number of layers in this stack
    params: Pytree            # leaves have leading dim n (except shared_attn)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)  # e.g. per-layer window


def stack_init(key, n: int, init_one) -> Pytree:
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


# ---------------------------------------------------------------------------
# per-kind layer bodies (one layer; scanned over the stack)
# ---------------------------------------------------------------------------

def _attn_mlp_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray, window: jnp.ndarray,
                    positions: Optional[jnp.ndarray]) -> jnp.ndarray:
    acfg = attn_cfg(cfg)._replace(sliding_window=0)
    b, s, _ = x.shape
    pos = positions if positions is not None else jnp.arange(s)
    h = rmsnorm(p["ln1"], x)
    # window is a traced per-layer scalar: build mask dynamically
    def attn_with_window(h):
        q = linear(p["attn"]["wq"], h).reshape(b, s, acfg.n_heads, acfg.head_dim)
        k = linear(p["attn"]["wk"], h).reshape(b, s, acfg.n_kv_heads, acfg.head_dim)
        v = linear(p["attn"]["wv"], h).reshape(b, s, acfg.n_kv_heads, acfg.head_dim)
        if acfg.qk_norm:
            q = rmsnorm(p["attn"]["q_norm"], q)
            k = rmsnorm(p["attn"]["k_norm"], k)
        q = attn_mod.apply_rope(q, pos, acfg.rope_theta)
        k = attn_mod.apply_rope(k, pos, acfg.rope_theta)
        groups = acfg.n_heads // acfg.n_kv_heads
        k = attn_mod._repeat_kv(k, groups)
        v = attn_mod._repeat_kv(v, groups)
        scale = 1.0 / math.sqrt(acfg.head_dim)
        if cfg.q_chunk and s > cfg.q_chunk:
            out = _attend_chunked_dynwin(q, k, v, pos, pos, scale, window, cfg.q_chunk)
        else:
            m = _dyn_mask(pos, pos, window)
            out = attn_mod.attend(q, k, v, m, scale)
        return linear(p["attn"]["wo"], out.reshape(b, s, acfg.n_heads * acfg.head_dim))

    x = x + attn_with_window(h)
    x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))
    return x


def _dyn_mask(q_pos, k_pos, window):
    m = q_pos[:, None] >= k_pos[None, :]
    win_m = (q_pos[:, None] - k_pos[None, :]) < jnp.maximum(window, 1)
    return jnp.where(window > 0, m & win_m, m)


def _attend_chunked_dynwin(q, k, v, q_pos, k_pos, scale, window, q_chunk):
    b, sq, h, d = q.shape
    q_chunk = attn_mod.largest_divisor_chunk(sq, q_chunk)
    n_chunks = sq // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, d).swapaxes(0, 1)
    pc = q_pos.reshape(n_chunks, q_chunk)

    def one(carry, xs):
        qi, pi = xs
        m = _dyn_mask(pi, k_pos, window)
        return carry, attn_mod.attend(qi, k, v, m, scale)

    _, outs = jax.lax.scan(one, None, (qc, pc))
    return outs.swapaxes(0, 1).reshape(b, sq, h, d)


def _attn_mlp_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_mod.gqa_init(k1, attn_cfg(cfg), dt),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dt),
    }


def _moe_layer(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    acfg = attn_cfg(cfg)
    if cfg.kv_lora_rank:
        x = x + attn_mod.mla_forward(p["attn"], mla_cfg(cfg), rmsnorm(p["ln1"], x), positions)
    else:
        x = x + attn_mod.gqa_forward(p["attn"], acfg, rmsnorm(p["ln1"], x), positions)
    out, aux = moe_mod.moe_forward(p["moe"], moe_cfg(cfg), rmsnorm(p["ln2"], x))
    return x + out, aux


def _moe_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    attn_p = (attn_mod.mla_init(k1, mla_cfg(cfg), dt) if cfg.kv_lora_rank
              else attn_mod.gqa_init(k1, attn_cfg(cfg), dt))
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attn_p,
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "moe": moe_mod.moe_init(k2, moe_cfg(cfg), dt),
    }


def _dense_first_layer(cfg: ModelConfig, p, x, positions):
    if cfg.kv_lora_rank:
        x = x + attn_mod.mla_forward(p["attn"], mla_cfg(cfg), rmsnorm(p["ln1"], x), positions)
    else:
        x = x + attn_mod.gqa_forward(p["attn"], attn_cfg(cfg), rmsnorm(p["ln1"], x), positions)
    return x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))


def _dense_first_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    attn_p = (attn_mod.mla_init(k1, mla_cfg(cfg), dt) if cfg.kv_lora_rank
              else attn_mod.gqa_init(k1, attn_cfg(cfg), dt))
    return {"ln1": rmsnorm_init(cfg.d_model, dt), "attn": attn_p,
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _mamba_layer(cfg: ModelConfig, p, x):
    return x + ssm_mod.mamba2_forward(p["mixer"], ssm_cfg(cfg), rmsnorm(p["ln"], x))


def _mamba_init(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    return {"ln": rmsnorm_init(cfg.d_model, dt),
            "mixer": ssm_mod.mamba2_init(key, ssm_cfg(cfg), dt)}


def _mlstm_layer(cfg: ModelConfig, p, x):
    return x + xlstm_mod.mlstm_forward(p["mixer"], xlstm_cfg(cfg), rmsnorm(p["ln"], x))


def _mlstm_init(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    return {"ln": rmsnorm_init(cfg.d_model, dt),
            "mixer": xlstm_mod.mlstm_init(key, xlstm_cfg(cfg), dt)}


def _slstm_layer(cfg: ModelConfig, p, x):
    return x + xlstm_mod.slstm_forward(p["mixer"], xlstm_cfg(cfg), rmsnorm(p["ln"], x))


def _slstm_init(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    return {"ln": rmsnorm_init(cfg.d_model, dt),
            "mixer": xlstm_mod.slstm_init(key, xlstm_cfg(cfg), dt)}


def _shared_attn_layer(cfg: ModelConfig, p, x, positions):
    """Zamba2-style shared attention block (full attention over d_model)."""
    return x + attn_mod.gqa_forward(p["attn"], attn_cfg(cfg)._replace(sliding_window=0),
                                    rmsnorm(p["ln"], x), positions)


def _shared_attn_init(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    return {"ln": rmsnorm_init(cfg.d_model, dt),
            "attn": attn_mod.gqa_init(key, attn_cfg(cfg), dt)}


# ---------------------------------------------------------------------------
# stack construction per architecture
# ---------------------------------------------------------------------------

def build_stacks(cfg: ModelConfig, key) -> List[BlockStack]:
    at = cfg.arch_type
    stacks: List[BlockStack] = []
    if at in ("dense", "vlm"):
        windows = _layer_windows(cfg)
        p = stack_init(key, cfg.n_layers, partial(_attn_mlp_init, cfg))
        stacks.append(BlockStack("attn_mlp", cfg.n_layers, p, {"window": windows}))
    elif at == "moe":
        k1, k2 = jax.random.split(key)
        if cfg.first_dense:
            p0 = stack_init(k1, cfg.first_dense, partial(_dense_first_init, cfg))
            stacks.append(BlockStack("dense_mlp", cfg.first_dense, p0))
        n_moe = cfg.n_layers - cfg.first_dense
        p = stack_init(k2, n_moe, partial(_moe_init, cfg))
        stacks.append(BlockStack("moe", n_moe, p))
    elif at == "ssm":
        # xLSTM: mLSTM blocks with sLSTM interleaved every slstm_every
        if cfg.slstm_every:
            idx = 0
            keys = jax.random.split(key, 2 * cfg.n_layers)
            ki = iter(keys)
            remaining = cfg.n_layers
            while remaining > 0:
                n_m = min(cfg.slstm_every - 1, remaining)
                if n_m > 0:
                    stacks.append(BlockStack(
                        "mlstm", n_m, stack_init(next(ki), n_m, partial(_mlstm_init, cfg))))
                    remaining -= n_m
                if remaining > 0:
                    stacks.append(BlockStack(
                        "slstm", 1, stack_init(next(ki), 1, partial(_slstm_init, cfg))))
                    remaining -= 1
        else:
            p = stack_init(key, cfg.n_layers, partial(_mamba_init, cfg))
            stacks.append(BlockStack("mamba", cfg.n_layers, p))
    elif at == "hybrid":
        # zamba2: mamba backbone, shared attention block every attn_every layers
        keys = jax.random.split(key, 64)
        ki = iter(keys)
        remaining = cfg.n_layers
        period = cfg.attn_every or cfg.n_layers
        # NOTE: zamba2 ties the weights of all shared-attn invocations; we give
        # each invocation its own params so the optimizer pytree stays a tree
        # (documented in DESIGN.md §Arch-applicability).
        while remaining > 0:
            n_m = min(period, remaining)
            stacks.append(BlockStack(
                "mamba", n_m, stack_init(next(ki), n_m, partial(_mamba_init, cfg))))
            remaining -= n_m
            if remaining > 0:
                stacks.append(BlockStack("shared_attn", 1, _shared_attn_init(cfg, next(ki))))
    elif at in ("encdec", "audio"):
        # decoder stacks only here; encoder built separately
        p = stack_init(key, cfg.n_layers, partial(_encdec_dec_init, cfg))
        stacks.append(BlockStack("dec_cross", cfg.n_layers, p))
    else:
        raise ValueError(f"unknown arch_type {at}")
    return stacks


def _layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding window sizes (0 = global)."""
    if cfg.global_every:
        w = [0 if (i + 1) % cfg.global_every == 0 else cfg.sliding_window
             for i in range(cfg.n_layers)]
    else:
        w = [cfg.sliding_window] * cfg.n_layers
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# encoder-decoder extras
# ---------------------------------------------------------------------------

def _encdec_enc_init(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg)
    return {"ln1": rmsnorm_init(cfg.d_model, dt),
            "attn": attn_mod.gqa_init(k1, attn_cfg(cfg), dt),
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}


def _encdec_enc_layer(cfg: ModelConfig, p, x):
    """Bidirectional encoder layer."""
    b, s, _ = x.shape
    acfg = attn_cfg(cfg)
    h = rmsnorm(p["ln1"], x)
    q = linear(p["attn"]["wq"], h).reshape(b, s, acfg.n_heads, acfg.head_dim)
    k = linear(p["attn"]["wk"], h).reshape(b, s, acfg.n_kv_heads, acfg.head_dim)
    v = linear(p["attn"]["wv"], h).reshape(b, s, acfg.n_kv_heads, acfg.head_dim)
    pos = jnp.arange(s)
    q = attn_mod.apply_rope(q, pos, acfg.rope_theta)
    k = attn_mod.apply_rope(k, pos, acfg.rope_theta)
    groups = acfg.n_heads // acfg.n_kv_heads
    k = attn_mod._repeat_kv(k, groups)
    v = attn_mod._repeat_kv(v, groups)
    mask = jnp.ones((s, s), bool)
    out = attn_mod.attend(q, k, v, mask, 1.0 / math.sqrt(acfg.head_dim))
    x = x + linear(p["attn"]["wo"], out.reshape(b, s, -1))
    return x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))


def _encdec_dec_init(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg)
    return {"ln1": rmsnorm_init(cfg.d_model, dt),
            "self_attn": attn_mod.gqa_init(k1, attn_cfg(cfg), dt),
            "ln_x": rmsnorm_init(cfg.d_model, dt),
            "cross_attn": attn_mod.gqa_init(k2, attn_cfg(cfg), dt),
            "ln2": rmsnorm_init(cfg.d_model, dt),
            "mlp": swiglu_init(k3, cfg.d_model, cfg.d_ff, dt)}


def _encdec_dec_layer(cfg: ModelConfig, p, x, memory, positions):
    acfg = attn_cfg(cfg)
    x = x + attn_mod.gqa_forward(p["self_attn"], acfg, rmsnorm(p["ln1"], x), positions)
    x = x + attn_mod.gqa_cross_forward(p["cross_attn"], acfg, rmsnorm(p["ln_x"], x), memory)
    return x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))


# ---------------------------------------------------------------------------
# stack execution (training / prefill)
# ---------------------------------------------------------------------------

def run_stack(cfg: ModelConfig, stack: BlockStack, x: jnp.ndarray,
              positions=None, memory=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss_sum)."""
    kind = stack.kind
    aux0 = jnp.zeros((), jnp.float32)

    if kind == "shared_attn":
        return _shared_attn_layer(cfg, stack.params, x, positions), aux0

    def body(carry, layer):
        x, aux = carry
        if kind == "attn_mlp":
            p, window = layer
            y = _attn_mlp_layer(cfg, p, x, window, positions)
        elif kind == "dense_mlp":
            y = _dense_first_layer(cfg, layer, x, positions)
        elif kind == "moe":
            y, a = _moe_layer(cfg, layer, x, positions)
            aux = aux + a
        elif kind == "mamba":
            y = _mamba_layer(cfg, layer, x)
        elif kind == "mlstm":
            y = _mlstm_layer(cfg, layer, x)
        elif kind == "slstm":
            y = _slstm_layer(cfg, layer, x)
        elif kind == "enc":
            y = _encdec_enc_layer(cfg, layer, x)
        elif kind == "dec_cross":
            y = _encdec_dec_layer(cfg, layer, x, memory, positions)
        else:
            raise ValueError(kind)
        return (y, aux), None

    fn = jax.checkpoint(body) if cfg.remat else body
    xs = (stack.params, stack.meta["window"]) if kind == "attn_mlp" else stack.params
    (x, aux), _ = jax.lax.scan(fn, (x, aux0), xs)
    return x, aux


# ---------------------------------------------------------------------------
# stack decode (single token)
# ---------------------------------------------------------------------------

def init_stack_cache(cfg: ModelConfig, stack: BlockStack, batch: int, max_seq: int,
                     dtype) -> Pytree:
    kind = stack.kind
    if kind in ("attn_mlp", "dense_mlp", "moe"):
        if kind != "attn_mlp" and cfg.kv_lora_rank:
            one = lambda: attn_mod.init_mla_cache(batch, max_seq, mla_cfg(cfg), dtype)
        else:
            one = lambda: attn_mod.init_kv_cache(batch, max_seq, attn_cfg(cfg), dtype)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[one() for _ in range(stack.n)]) \
            if stack.n > 1 else jax.tree.map(lambda x: x[None], one())
    if kind == "shared_attn":
        return attn_mod.init_kv_cache(batch, max_seq, attn_cfg(cfg), dtype)
    if kind == "mamba":
        one = lambda: ssm_mod.init_ssm_cache(batch, ssm_cfg(cfg), dtype)
    elif kind == "mlstm":
        one = lambda: xlstm_mod.init_mlstm_cache(batch, xlstm_cfg(cfg), dtype)
    elif kind == "slstm":
        one = lambda: xlstm_mod.init_slstm_cache(batch, xlstm_cfg(cfg), dtype)
    elif kind == "dec_cross":
        one = lambda: attn_mod.init_kv_cache(batch, max_seq, attn_cfg(cfg), dtype)
    else:
        raise ValueError(kind)
    trees = [one() for _ in range(stack.n)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees) if stack.n > 1 \
        else jax.tree.map(lambda x: x[None], trees[0])


def decode_stack(cfg: ModelConfig, stack: BlockStack, x: jnp.ndarray, cache: Pytree,
                 index: jnp.ndarray, memory=None) -> Tuple[jnp.ndarray, Pytree]:
    """One decode step through a stack.  x: (B, 1, d_model)."""
    kind = stack.kind
    if kind == "shared_attn":
        h = rmsnorm(stack.params["ln"], x)
        y, new_cache = attn_mod.gqa_decode(stack.params["attn"],
                                           attn_cfg(cfg)._replace(sliding_window=0),
                                           h, cache, index)
        return x + y, new_cache

    # scan over layers carrying x, threading caches
    if kind in ("attn_mlp", "dense_mlp", "moe", "dec_cross"):
        def scan_body(x, xs):
            if kind == "attn_mlp":
                (p, window), lcache = xs
                h = rmsnorm(p["ln1"], x)
                y, nc = _gqa_decode_dynwin(p["attn"], attn_cfg(cfg), h, lcache, index, window)
                x = x + y
                x = x + swiglu(p["mlp"], rmsnorm(p["ln2"], x))
                return x, nc
            layer, lcache = xs
            if kind == "dec_cross":
                h = rmsnorm(layer["ln1"], x)
                y, nc = attn_mod.gqa_decode(layer["self_attn"], attn_cfg(cfg), h, lcache, index)
                x = x + y
                x = x + attn_mod.gqa_cross_forward(layer["cross_attn"], attn_cfg(cfg),
                                                   rmsnorm(layer["ln_x"], x), memory)
                x = x + swiglu(layer["mlp"], rmsnorm(layer["ln2"], x))
                return x, nc
            h = rmsnorm(layer["ln1"], x)
            if cfg.kv_lora_rank:
                y, nc = attn_mod.mla_decode(layer["attn"], mla_cfg(cfg), h, lcache, index)
            else:
                y, nc = attn_mod.gqa_decode(layer["attn"], attn_cfg(cfg), h, lcache, index)
            x = x + y
            h2 = rmsnorm(layer["ln2"], x)
            if kind == "moe":
                out, _ = moe_mod.moe_forward(layer["moe"], moe_cfg(cfg), h2)
                x = x + out
            else:
                x = x + swiglu(layer["mlp"], h2)
            return x, nc

        xs = ((stack.params, stack.meta["window"]), cache) if kind == "attn_mlp" \
            else (stack.params, cache)
        x, new_cache = jax.lax.scan(scan_body, x, xs)
        return x, new_cache

    # recurrent kinds
    def scan_body_rec(x, xs):
        layer, lcache = xs
        h = rmsnorm(layer["ln"], x)
        if kind == "mamba":
            y, nc = ssm_mod.mamba2_decode(layer["mixer"], ssm_cfg(cfg), h, lcache)
        elif kind == "mlstm":
            y, nc = xlstm_mod.mlstm_decode(layer["mixer"], xlstm_cfg(cfg), h, lcache)
        elif kind == "slstm":
            y, nc = xlstm_mod.slstm_decode(layer["mixer"], xlstm_cfg(cfg), h, lcache)
        else:
            raise ValueError(kind)
        return x + y, nc

    x, new_cache = jax.lax.scan(scan_body_rec, x, (stack.params, cache))
    return x, new_cache


def _gqa_decode_dynwin(p, acfg: AttnConfig, x, cache, index, window):
    """gqa_decode with a traced per-layer window scalar."""
    b = x.shape[0]
    q = linear(p["wq"], x).reshape(b, 1, acfg.n_heads, acfg.head_dim)
    k_new = linear(p["wk"], x).reshape(b, 1, acfg.n_kv_heads, acfg.head_dim)
    v_new = linear(p["wv"], x).reshape(b, 1, acfg.n_kv_heads, acfg.head_dim)
    if acfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k_new = rmsnorm(p["k_norm"], k_new)
    pos = jnp.full((1,), index, dtype=jnp.int32)
    q = attn_mod.apply_rope(q, pos, acfg.rope_theta)
    k_new = attn_mod.apply_rope(k_new, pos, acfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))
    max_seq = k_cache.shape[1]
    k_pos = jnp.arange(max_seq)
    valid = k_pos <= index
    win_valid = (index - k_pos) < jnp.maximum(window, 1)
    valid = jnp.where(window > 0, valid & win_valid, valid)
    groups = acfg.n_heads // acfg.n_kv_heads
    k_all = attn_mod._repeat_kv(k_cache, groups)
    v_all = attn_mod._repeat_kv(v_cache, groups)
    out = attn_mod.attend(q, k_all, v_all, valid[None, :], 1.0 / math.sqrt(acfg.head_dim))
    y = linear(p["wo"], out.reshape(b, 1, acfg.n_heads * acfg.head_dim))
    return y, {"k": k_cache, "v": v_cache}
