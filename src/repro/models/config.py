"""ModelConfig — single declarative description of every supported
architecture family (dense / moe / ssm / hybrid / encdec / vlm / audio and
the paper's CNNs)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0           # 0 => full attention everywhere
    global_every: int = 0             # gemma3-style: every k-th layer is global
    q_chunk: int = 0                  # scan-chunked attention for long seqs

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    first_dense: int = 0              # first k layers use a dense MLP
    capacity_factor: float = 1.25

    # MLA (deepseek)
    kv_lora_rank: int = 0
    rope_dim: int = 64

    # SSM / hybrid
    ssm_state: int = 0
    ssm_chunk: int = 256
    attn_every: int = 0               # hybrid: shared attention every k ssm blocks

    # xLSTM
    slstm_every: int = 0              # every k-th block is sLSTM (0 => all mLSTM)

    # encdec / multimodal
    n_enc_layers: int = 0
    n_prefix_tokens: int = 0          # vlm patches / audio frames consumed as embeddings

    # split-learning integration
    cut_layer: int = 1                # client-side block count (the SL cut)

    # execution
    remat: bool = False
    loss_chunk: int = 0               # scan-chunked xent (0 => full logits)
    dtype: str = "float32"
    # named beyond-baseline optimizations (set by the launch layer only —
    # they emit mesh-axis sharding constraints and require a mesh context):
    #   "moe_shard"    — token/capacity-sharded MoE dispatch (all-to-all)
    #   "mlstm_bf16_state" — bf16 inter-chunk mLSTM state carries
    # ("pigeon_psum" retired: the one-hot psum winner broadcast is now the
    #  RoundRunner's only strategy — see core/runner.py)
    optimizations: Tuple[str, ...] = ()

    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs accounting)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d
        per_attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.kv_lora_rank:
            per_attn = (d * self.n_heads * (hd + self.rope_dim)
                        + d * (self.kv_lora_rank + self.rope_dim)
                        + self.kv_lora_rank * self.n_heads * hd * 2
                        + self.n_heads * hd * d)
        per_mlp = 3 * d * self.d_ff
        per_moe = self.n_experts * 3 * d * self.d_expert + d * self.n_experts \
            + self.n_shared_experts * 3 * d * self.d_expert
        n = emb * 2  # embed + head (untied)
        if self.arch_type in ("dense", "vlm"):
            n += self.n_layers * (per_attn + per_mlp)
        elif self.arch_type == "moe":
            n += self.first_dense * (per_attn + per_mlp)
            n += (self.n_layers - self.first_dense) * (per_attn + per_moe)
        elif self.arch_type == "ssm":
            di = 2 * d
            per_blk = d * (2 * di + 2 * self.ssm_state + di // 64) + di * d
            n += self.n_layers * per_blk
        elif self.arch_type == "hybrid":
            di = 2 * d
            per_blk = d * (2 * di + 2 * self.ssm_state + di // 64) + di * d
            n += self.n_layers * per_blk + 2 * per_attn
        elif self.arch_type in ("encdec", "audio"):
            n += (self.n_enc_layers or self.n_layers) * (per_attn + per_mlp)
            n += self.n_layers * (2 * per_attn + per_mlp)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.arch_type != "moe":
            return self.param_count()
        d = self.d_model
        per_attn = d * (self.n_heads * self.resolved_head_dim) * 2 \
            + d * (self.n_kv_heads * self.resolved_head_dim) * 2
        if self.kv_lora_rank:
            hd = self.resolved_head_dim
            per_attn = (d * self.n_heads * (hd + self.rope_dim)
                        + d * (self.kv_lora_rank + self.rope_dim)
                        + self.kv_lora_rank * self.n_heads * hd * 2
                        + self.n_heads * hd * d)
        per_active_moe = (self.top_k + self.n_shared_experts) * 3 * d * self.d_expert \
            + d * self.n_experts
        n = self.vocab * d * 2
        n += self.first_dense * (per_attn + 3 * d * self.d_ff)
        n += (self.n_layers - self.first_dense) * (per_attn + per_active_moe)
        return n


def reduce_config(cfg: ModelConfig, n_layers: int = 2, d_model: int = 256,
                  vocab: int = 512, n_experts: int = 4) -> ModelConfig:
    """Smoke-test variant of the same family (<=2 layers, d_model<=512,
    <=4 experts) that runs a real forward/train step on CPU."""
    d_model = min(d_model, 512)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    scale = max(1, cfg.d_ff // max(cfg.d_model, 1)) if cfg.d_ff else 0
    changes = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=scale * d_model if cfg.d_ff else 0,
        vocab=min(cfg.vocab, vocab),
        head_dim=d_model // n_heads,
        q_chunk=0,
        ssm_chunk=64,
        remat=False,
        loss_chunk=0,
        dtype="float32",
        name=cfg.name + "-smoke",
    )
    if cfg.n_experts:
        changes.update(
            n_experts=min(cfg.n_experts, n_experts),
            top_k=min(cfg.top_k, 2),
            d_expert=d_model // 2,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            first_dense=min(cfg.first_dense, 1),
        )
    if cfg.kv_lora_rank:
        changes.update(kv_lora_rank=64, rope_dim=32)
    if cfg.ssm_state:
        changes.update(ssm_state=16)
    if cfg.attn_every:
        changes.update(attn_every=min(cfg.attn_every, 2))
    if cfg.slstm_every:
        changes.update(slstm_every=2)
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=2)
    if cfg.n_prefix_tokens:
        changes.update(n_prefix_tokens=8)
    if cfg.global_every:
        changes.update(global_every=2, sliding_window=16)
    elif cfg.sliding_window:
        changes.update(sliding_window=16)
    changes["cut_layer"] = 1
    return dataclasses.replace(cfg, **changes)
