"""Attention layers: GQA (with RoPE / QK-norm / bias / sliding window) and MLA.

Two execution paths:
  * ``attend``         — reference path, materialises the (q, k) score matrix.
  * ``attend_chunked`` — scan over query chunks; O(chunk * seq) live memory.
                         This is the XLA-level "flash" path used for long
                         sequences in the dry-run; the Pallas kernel in
                         ``repro.kernels.flash_attention`` is the TPU hot-path.

Decode path keeps a (batch, max_seq, kv_heads, head_dim) cache per layer and
supports sliding-window eviction-free masking (we mask instead of evicting so
that the cache layout stays static for XLA).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .blocks import Params, apply_rope, linear, linear_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0           # 0 => full/global attention
    softmax_scale: Optional[float] = None
    q_chunk: int = 0                  # 0 => un-chunked reference path


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": linear_init(kq, cfg.d_model, cfg.n_heads * cfg.head_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wk": linear_init(kk, cfg.d_model, cfg.n_kv_heads * cfg.head_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wv": linear_init(kv, cfg.d_model, cfg.n_kv_heads * cfg.head_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wo": linear_init(ko, cfg.n_heads * cfg.head_dim, cfg.d_model, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype)
    return p


# ---------------------------------------------------------------------------
# masking helpers
# ---------------------------------------------------------------------------

def causal_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int = 0) -> jnp.ndarray:
    """(q, k) boolean mask — True means *attend*."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, D) -> (B, S, Hkv*groups, D)."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray,
           scale: float) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Sk, H, D); mask: (Sq, Sk) or (B, Sq, Sk)."""
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask = mask[None, None, :, :]
    else:
        mask = mask[:, None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attend_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   q_pos: jnp.ndarray, k_pos: jnp.ndarray, scale: float,
                   window: int, q_chunk: int) -> jnp.ndarray:
    """Scan over query chunks to bound live memory (XLA flash equivalent)."""
    b, sq, h, d = q.shape
    dv = v.shape[-1]                      # MLA: value dim != query dim
    q_chunk = largest_divisor_chunk(sq, q_chunk)
    n_chunks = sq // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(n_chunks, q_chunk)

    def one_chunk(carry, xs):
        qi, pi = xs
        m = causal_mask(pi, k_pos, window)
        out = attend(qi, k, v, m, scale)
        return carry, out

    _, outs = jax.lax.scan(one_chunk, None, (qc, pc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dv)


def largest_divisor_chunk(s: int, chunk: int) -> int:
    """Largest chunk <= requested that divides s (seqs like 3840 = 4096-256
    patches aren't powers of two)."""
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    return chunk


# ---------------------------------------------------------------------------
# full layer forward (training / prefill)
# ---------------------------------------------------------------------------

def gqa_forward(p: Params, cfg: AttnConfig, x: jnp.ndarray,
                positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Causal self-attention over a full sequence.  x: (B, S, d_model)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.head_dim))
    if cfg.q_chunk and s > cfg.q_chunk:
        out = attend_chunked(q, k, v, positions, positions, scale, cfg.sliding_window, cfg.q_chunk)
    else:
        mask = causal_mask(positions, positions, cfg.sliding_window)
        out = attend(q, k, v, mask, scale)
    return linear(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.head_dim))


def gqa_cross_forward(p: Params, cfg: AttnConfig, x: jnp.ndarray,
                      memory: jnp.ndarray) -> jnp.ndarray:
    """Cross-attention (no causal mask, no rope on memory side positions
    beyond index order).  Used by the encoder-decoder architecture."""
    b, sq, _ = x.shape
    sk = memory.shape[1]
    q = linear(p["wq"], x).reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], memory).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], memory).reshape(b, sk, cfg.n_kv_heads, cfg.head_dim)
    groups = cfg.n_heads // cfg.n_kv_heads
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.head_dim))
    mask = jnp.ones((sq, sk), dtype=bool)
    out = attend(q, k, v, mask, scale)
    return linear(p["wo"], out.reshape(b, sq, cfg.n_heads * cfg.head_dim))


# ---------------------------------------------------------------------------
# decode (single-token) with KV cache
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_seq: int, cfg: AttnConfig, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def gqa_decode(p: Params, cfg: AttnConfig, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               index: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One decode step.  x: (B, 1, d_model); cache holds max_seq positions;
    ``index`` is the scalar position of the new token."""
    b = x.shape[0]
    q = linear(p["wq"], x).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k_new = linear(p["wk"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v_new = linear(p["wv"], x).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k_new = rmsnorm(p["k_norm"], k_new)
    pos = jnp.full((1,), index, dtype=jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, index, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, index, 0, 0))

    max_seq = k_cache.shape[1]
    k_pos = jnp.arange(max_seq)
    valid = k_pos <= index
    if cfg.sliding_window > 0:
        valid = valid & (index - k_pos < cfg.sliding_window)

    groups = cfg.n_heads // cfg.n_kv_heads
    k_all = _repeat_kv(k_cache, groups)
    v_all = _repeat_kv(v_cache, groups)
    scale = cfg.softmax_scale or (1.0 / math.sqrt(cfg.head_dim))
    out = attend(q, k_all, v_all, valid[None, :], scale)
    y = linear(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2), decode caches the latent.
# ---------------------------------------------------------------------------

class MLAConfig(NamedTuple):
    d_model: int
    n_heads: int
    head_dim: int
    kv_lora_rank: int
    rope_dim: int = 64            # decoupled rope sub-dimension
    rope_theta: float = 10000.0
    q_chunk: int = 0


def mla_init(key, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "wq": linear_init(ks[0], cfg.d_model, cfg.n_heads * (cfg.head_dim + cfg.rope_dim), dtype=dtype),
        # joint KV low-rank compression + decoupled shared rope key
        "w_dkv": linear_init(ks[1], cfg.d_model, cfg.kv_lora_rank + cfg.rope_dim, dtype=dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "w_uk": linear_init(ks[2], cfg.kv_lora_rank, cfg.n_heads * cfg.head_dim, dtype=dtype),
        "w_uv": linear_init(ks[3], cfg.kv_lora_rank, cfg.n_heads * cfg.head_dim, dtype=dtype),
        "wo": linear_init(ks[4], cfg.n_heads * cfg.head_dim, cfg.d_model, dtype=dtype),
    }


def mla_forward(p: Params, cfg: MLAConfig, x: jnp.ndarray,
                positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence MLA.  Content path is rope-free (latent-cacheable); a
    decoupled rope sub-key carries position, shared across heads."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q_full = linear(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim + cfg.rope_dim)
    q_c, q_r = q_full[..., : cfg.head_dim], q_full[..., cfg.head_dim:]
    dkv = linear(p["w_dkv"], x)
    latent = rmsnorm(p["kv_norm"], dkv[..., : cfg.kv_lora_rank])
    k_rope = dkv[..., cfg.kv_lora_rank:].reshape(b, s, 1, cfg.rope_dim)
    k_c = linear(p["w_uk"], latent).reshape(b, s, cfg.n_heads, cfg.head_dim)
    v = linear(p["w_uv"], latent).reshape(b, s, cfg.n_heads, cfg.head_dim)
    q_r = apply_rope(q_r, positions, cfg.rope_theta)
    k_r = apply_rope(k_rope, positions, cfg.rope_theta)
    k_r = jnp.broadcast_to(k_r, (b, s, cfg.n_heads, cfg.rope_dim))
    q = jnp.concatenate([q_c, q_r], axis=-1)
    k = jnp.concatenate([k_c, k_r], axis=-1)
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_dim)
    if cfg.q_chunk and s > cfg.q_chunk:
        out = attend_chunked(q, k, v, positions, positions, scale, 0, cfg.q_chunk)
    else:
        mask = causal_mask(positions, positions)
        out = attend(q, k, v, mask, scale)
    return linear(p["wo"], out.reshape(b, s, cfg.n_heads * cfg.head_dim))


def init_mla_cache(batch: int, max_seq: int, cfg: MLAConfig, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """MLA decode cache: compressed latent + shared rope key (the whole point
    of MLA — cache is rank+rope_dim wide instead of 2*heads*head_dim)."""
    return {
        "latent": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.rope_dim), dtype),
    }


def mla_decode(p: Params, cfg: MLAConfig, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
               index: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    b = x.shape[0]
    q_full = linear(p["wq"], x).reshape(b, 1, cfg.n_heads, cfg.head_dim + cfg.rope_dim)
    q_c, q_r = q_full[..., : cfg.head_dim], q_full[..., cfg.head_dim:]
    pos = jnp.full((1,), index, dtype=jnp.int32)
    q_r = apply_rope(q_r, pos, cfg.rope_theta)
    dkv = linear(p["w_dkv"], x)
    latent_new = rmsnorm(p["kv_norm"], dkv[..., : cfg.kv_lora_rank])
    k_rope_new = apply_rope(dkv[..., cfg.kv_lora_rank:].reshape(b, 1, 1, cfg.rope_dim), pos,
                            cfg.rope_theta).reshape(b, 1, cfg.rope_dim)
    latent = jax.lax.dynamic_update_slice(cache["latent"], latent_new.astype(cache["latent"].dtype), (0, index, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), (0, index, 0))

    # absorb: score = q_c^T W_uk latent + q_r^T k_rope
    w_uk = p["w_uk"]["w"].reshape(cfg.kv_lora_rank, cfg.n_heads, cfg.head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_c, w_uk)               # (b,1,h,rank)
    scores_c = jnp.einsum("bqhr,bkr->bhqk", q_lat, latent)
    scores_r = jnp.einsum("bqhd,bkd->bhqk", q_r, k_rope)
    scale = 1.0 / math.sqrt(cfg.head_dim + cfg.rope_dim)
    scores = (scores_c + scores_r).astype(jnp.float32) * scale
    max_seq = latent.shape[1]
    valid = jnp.arange(max_seq) <= index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(latent.dtype)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", probs, latent)         # (b,1,h,rank)
    w_uv = p["w_uv"]["w"].reshape(cfg.kv_lora_rank, cfg.n_heads, cfg.head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_uv)
    y = linear(p["wo"], out.reshape(b, 1, cfg.n_heads * cfg.head_dim))
    return y, {"latent": latent, "k_rope": k_rope}
