from .optim import (Optimizer, adamw, cosine_schedule, clip_by_global_norm,
                    constant_schedule, sgd, warmup_cosine)

__all__ = ["Optimizer", "sgd", "adamw", "constant_schedule", "cosine_schedule",
           "warmup_cosine", "clip_by_global_norm"]
