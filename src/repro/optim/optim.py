"""Minimal pure-JAX optimizer library (the container has no optax).

``Optimizer`` is an (init, update) pair over arbitrary pytrees:

    opt = adamw(warmup_cosine(3e-4, 100, 10_000))
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = jax.tree.map(lambda p, u: p + u, params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1) -> Schedule:
    def fn(step):
        t = jnp.minimum(step / max(total_steps, 1), 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int,
                  final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))
    return fn


def _as_schedule(lr) -> Schedule:
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# transforms
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(jnp.zeros_like, params)
        return state

    def update(grads, state, params=None):
        lr_t = sched(state["step"])
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            updates = jax.tree.map(lambda m: (-lr_t * m).astype(m.dtype), mu)
            new_state = {"step": state["step"] + 1, "mu": mu}
        else:
            updates = jax.tree.map(lambda g: (-lr_t * g).astype(g.dtype), grads)
            new_state = {"step": state["step"] + 1}
        return updates, new_state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(state["step"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
