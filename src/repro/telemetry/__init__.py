"""Telemetry subsystem: round-span tracing, on-device-fenced timing,
per-round metrics and provenance-stamped event logs.

The protocol stack's observability layer (see README "Observability"):

* :mod:`trace`      — nested monotonic-clock spans with explicit
                      ``block_until_ready`` fencing at span exit, plus the
                      :class:`Stopwatch` timer helper the launch scripts use.
* :mod:`metrics`    — per-round gauges and run counters, populated from the
                      batched path's existing single stacked host fetch (no
                      extra device→host syncs).
* :mod:`sinks`      — JSONL event log (crash-tolerant append), in-memory
                      sink for tests, console sink (the ``verbose=True``
                      replacement).
* :mod:`profile`    — opt-in windowed ``jax.profiler`` trace hooks.
* :mod:`provenance` — the environment stamp (jax/jaxlib, backend, device
                      kind, cpu count, git sha, timestamp) shared by traces
                      and benchmark JSONs.
* :mod:`session`    — the :class:`Telemetry` config object threaded through
                      ``ProtocolConfig``/driver kwargs and the per-run
                      :class:`TelemetrySession` runtime.

Telemetry is a strict no-op on the math: it consumes no RNG streams and
dispatches no device ops, so a telemetry-enabled run produces a
bit-identical ``History`` and CommMeter to a disabled one
(``tests/test_telemetry.py`` pins this across engines × placements ×
prefetch).
"""
from .metrics import (MetricsRegistry, jit_cache_stats, pool_gauges,
                      round_gauges)
from .profile import ProfileHook
from .provenance import provenance
from .session import (DISABLED, NULL_SESSION, NullSession, Telemetry,
                      TelemetrySession, resolve_telemetry)
from .sinks import (ConsoleSink, JSONLSink, MemorySink, MultiSink, Sink,
                    read_jsonl)
from .trace import NULL_SPAN, NULL_TRACER, Span, Stopwatch, Tracer

__all__ = [
    "Telemetry", "TelemetrySession", "NullSession", "NULL_SESSION",
    "DISABLED", "resolve_telemetry",
    "Tracer", "Span", "Stopwatch", "NULL_TRACER", "NULL_SPAN",
    "MetricsRegistry", "round_gauges", "pool_gauges", "jit_cache_stats",
    "Sink", "JSONLSink", "MemorySink", "ConsoleSink", "MultiSink",
    "read_jsonl",
    "ProfileHook", "provenance",
]
