"""Run provenance: the environment stamp carried by every telemetry trace
and benchmark JSON.

A benchmark number or a JSONL trace without the software/hardware context
that produced it cannot be compared across commits — perf trajectories in
``experiments/`` span many PRs and (eventually) many machines.  The stamp
records the jax/jaxlib versions, the backend and device kind, host CPU
count, the repo's git revision and a timestamp; everything degrades to
``None`` rather than raising, so provenance can never break a run.
"""
from __future__ import annotations

import os
import platform
import subprocess
import sys
import time
from functools import lru_cache
from typing import Any, Dict, Optional


@lru_cache(maxsize=1)
def _git_sha() -> Optional[str]:
    """Repo revision (with a ``-dirty`` suffix when the tree has local
    modifications); None outside a git checkout or without git."""
    root = os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root, capture_output=True,
            text=True, timeout=5, check=True).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root, capture_output=True,
            text=True, timeout=5, check=True).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except (OSError, subprocess.SubprocessError):
        return None


@lru_cache(maxsize=1)
def _static_provenance() -> Dict[str, Any]:
    out: Dict[str, Any] = {
        "python": sys.version.split()[0],
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "git_sha": _git_sha(),
    }
    try:
        import jax
        out["jax"] = jax.__version__
        try:
            import jaxlib
            out["jaxlib"] = jaxlib.version.__version__
        except (ImportError, AttributeError):
            out["jaxlib"] = None
        out["backend"] = jax.default_backend()
        devs = jax.devices()
        out["device_kind"] = devs[0].device_kind if devs else None
        out["device_count"] = len(devs)
    except Exception:  # noqa: BLE001 — provenance must never break a run
        out.setdefault("jax", None)
    return out


def provenance(**extra: Any) -> Dict[str, Any]:
    """The environment stamp: jax/jaxlib versions, backend + device kind,
    cpu count, git sha and timestamp (both epoch seconds and UTC ISO).  The
    expensive lookups are cached; the timestamp is fresh per call."""
    out = dict(_static_provenance())
    now = time.time()
    out["timestamp"] = now
    out["timestamp_utc"] = time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                         time.gmtime(now))
    out.update(extra)
    return out
