"""Opt-in ``jax.profiler`` trace hooks.

Span timings answer *where a round's wall-clock goes*; the XLA profiler
answers *what the device did inside the step*.  The hook is deliberately
windowed — profiling every round of a long run produces gigabytes of trace
— and failure-tolerant: a missing profiler backend (no tensorboard plugin,
unsupported platform) degrades to a warning once, never an exception, so a
``Telemetry(profile_dir=...)`` config can be left in place on machines that
cannot profile.

Drivers call :meth:`ProfileHook.tick` once at the top of every round; the
hook starts the trace when the window opens and stops it when the window
closes (or on :meth:`close`, for runs shorter than the window).
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple


DEFAULT_WINDOW = (1, 2)   # profile round 1 only: steady state, post-compile


class ProfileHook:
    """Round-windowed ``jax.profiler`` trace: profiles rounds ``t`` with
    ``start <= t < stop`` into ``trace_dir``.  ``rounds=None`` uses
    :data:`DEFAULT_WINDOW` — round 1 only, skipping round 0's trace/compile
    so the trace shows the steady-state program."""

    def __init__(self, trace_dir: str,
                 rounds: Optional[Tuple[int, int]] = None):
        self.trace_dir = trace_dir
        self.start, self.stop = rounds if rounds is not None else DEFAULT_WINDOW
        self._running = False
        self._broken = False

    def tick(self, t: int) -> None:
        """Advance the window to round ``t`` (called once per round, at the
        top, before any device work for the round is dispatched)."""
        if self._broken:
            return
        if self._running and t >= self.stop:
            self._stop()
        if not self._running and self.start <= t < self.stop:
            self._start()

    def _start(self) -> None:
        try:
            import jax
            jax.profiler.start_trace(self.trace_dir)
            self._running = True
        except Exception as e:  # noqa: BLE001 — profiling is best-effort
            self._broken = True
            warnings.warn(f"telemetry: jax.profiler trace unavailable "
                          f"({type(e).__name__}: {e}); profiling disabled "
                          f"for this run", stacklevel=3)

    def _stop(self) -> None:
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception as e:  # noqa: BLE001
            self._broken = True
            warnings.warn(f"telemetry: jax.profiler stop_trace failed "
                          f"({type(e).__name__}: {e})", stacklevel=3)
        finally:
            self._running = False

    def close(self) -> None:
        if self._running:
            self._stop()
