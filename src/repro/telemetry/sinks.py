"""Telemetry sinks: where events go.

Every sink consumes plain-dict events (spans, per-round metric records,
run start/end markers).  Three implementations:

* :class:`JSONLSink` — one JSON object per line, crash-tolerant append: each
  event is flushed as a complete line, an existing file whose tail was torn
  by a crash is newline-healed before new events are appended, and the
  reader (:func:`read_jsonl`) skips torn/unparseable lines instead of
  failing — the same durability posture as the checkpoint layer, adapted to
  an append-only log.
* :class:`MemorySink` — in-process event list, for tests and programmatic
  inspection.
* :class:`ConsoleSink` — one uniform human-readable line per protocol round;
  the replacement for the drivers' historical ad-hoc ``verbose`` prints.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import numpy as np


def materialize(event: Any) -> Any:
    """One up-front host materialization of an event tree.

    Array-like values (numpy or device arrays) are pulled with a **single**
    ``np.asarray`` each and converted to nested Python lists/scalars here,
    before serialization — the encoder never walks a device array
    element-by-element (the historical ``.item()``-per-scalar default
    encoder issued one device sync per element mid-``json.dumps``)."""
    if isinstance(event, dict):
        return {k: materialize(v) for k, v in event.items()}
    if isinstance(event, (list, tuple)):
        return [materialize(v) for v in event]
    if isinstance(event, (str, bool, int, float)) or event is None:
        return event
    if isinstance(event, np.generic):
        return event.item()
    if isinstance(event, np.ndarray) or hasattr(event, "__array__"):
        arr = np.asarray(event)
        return arr.item() if arr.ndim == 0 else arr.tolist()
    return event


def _jsonable(o: Any) -> Any:
    """Last-resort encoder for exotic types that survive materialization."""
    if hasattr(o, "item") and not hasattr(o, "__len__"):
        return o.item()
    if hasattr(o, "tolist"):
        return o.tolist()
    return str(o)


class Sink:
    """Event consumer.  ``emit`` must tolerate being called from multiple
    threads *in sequence* (the session serialises calls under its lock)."""

    def emit(self, event: Dict[str, Any]) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class MemorySink(Sink):
    """Collects events in a list (``sink.events``)."""

    def __init__(self) -> None:
        self.events: List[Dict[str, Any]] = []

    def emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)

    def of(self, kind: str) -> List[Dict[str, Any]]:
        """Events of one kind (``event == kind``)."""
        return [e for e in self.events if e.get("event") == kind]


class JSONLSink(Sink):
    """Append-only JSONL event log.

    Durability: every event is written as one complete line and flushed, so
    a crash can tear at most the line in flight.  On open, a pre-existing
    file that does not end in a newline (a torn tail) is healed with a
    single ``"\\n"`` so the next event starts on a fresh line — the torn
    line stays in the file (the reader skips it) but cannot corrupt events
    written after the restart.
    """

    def __init__(self, path: str, fsync: bool = False):
        self.path = path
        self._fsync = fsync
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        needs_heal = False
        if os.path.exists(path) and os.path.getsize(path) > 0:
            with open(path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                needs_heal = f.read(1) != b"\n"
        self._f = open(path, "a", encoding="utf-8")
        if needs_heal:
            self._f.write("\n")
            self._f.flush()

    def emit(self, event: Dict[str, Any]) -> None:
        self._f.write(json.dumps(materialize(event), default=_jsonable) + "\n")
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    """Parse a JSONL event log, skipping torn/unparseable lines (a crash can
    leave at most one mid-write tear per process generation; healed files
    keep the torn fragment as its own line).  Returns the complete events in
    file order."""
    events: List[Dict[str, Any]] = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                continue            # torn line — tolerated by contract
    return events


class ConsoleSink(Sink):
    """One uniform line per protocol round — the ``verbose=True``
    replacement.  Fields missing from a driver's record (e.g. vanilla SL has
    no selection) are simply omitted, so every driver shares one format
    instead of the historical three ad-hoc prints."""

    def __init__(self, stream=None):
        self._stream = stream

    def emit(self, event: Dict[str, Any]) -> None:
        if event.get("event") != "round":
            return
        parts = [f"[{event.get('run', '?')}] t={int(event.get('t', -1)):3d}"]
        acc = event.get("test_acc")
        parts.append(f"acc={acc:.4f}" if acc is not None else "acc=nan")
        if "selected" in event:
            parts.append(f"sel={event['selected']}")
        if "selected_honest" in event:
            parts.append(f"honest={event['selected_honest']}")
        if "accepted" in event:
            parts.append(f"accepted={event['accepted']}")
        if "detections" in event:
            parts.append(f"det={event['detections']}")
        if "train_loss" in event:
            parts.append(f"tloss={event['train_loss']:.4f}")
        if "val_losses" in event:
            vl = ",".join(f"{v:.4f}" for v in event["val_losses"])
            parts.append(f"vloss=[{vl}]")
        print(" ".join(parts), flush=True, file=self._stream)


class MultiSink(Sink):
    def __init__(self, sinks):
        self.sinks = list(sinks)

    def emit(self, event: Dict[str, Any]) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()
