"""The :class:`Telemetry` config object and the per-run session it opens.

``Telemetry`` is a frozen, declarative config — *what* to record and where
to send it — safe to embed in :class:`~repro.core.ProtocolConfig`, pass as a
driver kwarg, or share across several runs (each run opens its own
session).  :class:`TelemetrySession` is the runtime: it owns the span
tracer, the metrics registry, the sink fan-out (serialised under one lock so
the RoundFeeder's producer thread can emit concurrently with the main loop)
and the optional profiler hook, and it stamps every run with a provenance
header (``run_start`` event).

``resolve_telemetry`` is the drivers' single entry point.  It implements the
``verbose=True`` back-compat contract — verbose is now an alias for the
console sink — and returns the shared no-op session when telemetry is
disabled, so the hot loop's cost in the disabled case is a handful of no-op
method calls per round.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, Optional, Tuple

from .metrics import MetricsRegistry, jit_cache_stats, round_gauges
from .profile import ProfileHook
from .provenance import provenance
from .sinks import ConsoleSink, JSONLSink, MemorySink, Sink
from .trace import NULL_SPAN, NULL_TRACER, NullSpan, Tracer


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Declarative telemetry config, threaded through
    ``ProtocolConfig.telemetry`` / the drivers' ``telemetry=`` kwarg, the
    launch scripts and the benchmark entrypoint.

    ``jsonl``      — path of the append-only JSONL event log (None = off).
    ``console``    — per-round console lines (what ``verbose=True`` enables).
    ``sinks``      — extra :class:`~repro.telemetry.sinks.Sink` instances
                     (e.g. a :class:`MemorySink` for tests); the session
                     emits to these but does NOT close them, so one sink can
                     observe several runs.
    ``spans``      — emit phase spans (off leaves only round records).
    ``jit_stats``  — include compiled-program cache stats in round records.
    ``profile_dir``/``profile_rounds`` — windowed ``jax.profiler`` trace
                     (see :mod:`repro.telemetry.profile`).
    """
    enabled: bool = True
    jsonl: Optional[str] = None
    console: bool = False
    sinks: Tuple[Sink, ...] = ()
    spans: bool = True
    jit_stats: bool = False
    profile_dir: Optional[str] = None
    profile_rounds: Optional[Tuple[int, int]] = None

    def session(self, run: str = "", **meta: Any) -> "TelemetrySession":
        """Open a per-run session (emits the provenance-stamped
        ``run_start`` header immediately)."""
        return TelemetrySession(self, run=run, meta=meta)


DISABLED = Telemetry(enabled=False)


class TelemetrySession:
    """One run's live telemetry.  Use as a context manager (``close`` emits
    the ``run_end`` summary and closes owned sinks)."""

    enabled = True

    def __init__(self, cfg: Telemetry, run: str = "",
                 meta: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.run = run
        self.metrics = MetricsRegistry()
        self._lock = threading.Lock()
        self._sinks = list(cfg.sinks)
        self._owned: list = []
        if cfg.jsonl:
            s = JSONLSink(cfg.jsonl)
            self._sinks.append(s)
            self._owned.append(s)
        if cfg.console:
            s = ConsoleSink()
            self._sinks.append(s)
            self._owned.append(s)
        self.tracer = Tracer(self._emit) if cfg.spans else NULL_TRACER
        self._profile = (ProfileHook(cfg.profile_dir, cfg.profile_rounds)
                         if cfg.profile_dir else None)
        self._closed = False
        self._emit({"event": "run_start", "provenance": provenance(),
                    **(meta or {})})

    # -- events -------------------------------------------------------------

    def _emit(self, event: Dict[str, Any]) -> None:
        event.setdefault("run", self.run)
        with self._lock:
            for s in self._sinks:
                s.emit(event)

    def emit(self, event: Dict[str, Any]) -> None:
        """Emit a custom event (must carry an ``event`` kind key)."""
        self._emit(event)

    # -- spans --------------------------------------------------------------

    def span(self, name: str, **attrs: Any):
        """A nested phase span (``with tel.span("round.step", round=t) as
        sp: ...; sp.fence(outputs)``)."""
        return self.tracer.span(name, **attrs)

    # -- per-round metrics --------------------------------------------------

    def record_round(self, t: int, rec: Dict[str, Any],
                     feeder_depth: Optional[int] = None,
                     **extra: Any) -> None:
        """Fold one driver History record into the metrics registry and emit
        the per-round ``round`` event.  Everything read here is a host-side
        Python value the driver already fetched — no device sync."""
        self.metrics.observe_round(rec)
        event: Dict[str, Any] = {"event": "round", "t": int(t)}
        event.update(round_gauges(rec, feeder_depth))
        if self.cfg.jit_stats:
            event["jit"] = jit_cache_stats()
        event.update(extra)
        self._emit(event)

    # -- profiler window ----------------------------------------------------

    def profile_tick(self, t: int) -> None:
        """Advance the optional ``jax.profiler`` window to round ``t``."""
        if self._profile is not None:
            self._profile.tick(t)

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._profile is not None:
            self._profile.close()
        self._emit({"event": "run_end", "metrics": self.metrics.snapshot()})
        for s in self._owned:
            s.close()

    def __enter__(self) -> "TelemetrySession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class NullSession:
    """The disabled session: every method is a no-op and ``span`` returns
    the shared :class:`NullSpan`.  A single instance serves every disabled
    run — it holds no state and ``close`` does nothing."""

    enabled = False
    metrics = None
    run = ""

    def span(self, name: str, **attrs: Any) -> NullSpan:
        return NULL_SPAN

    def emit(self, event: Dict[str, Any]) -> None:
        pass

    def record_round(self, t: int, rec: Dict[str, Any],
                     feeder_depth: Optional[int] = None,
                     **extra: Any) -> None:
        pass

    def profile_tick(self, t: int) -> None:
        pass

    def close(self) -> None:
        pass

    def __enter__(self) -> "NullSession":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SESSION = NullSession()


class _BorrowedSession:
    """A caller-owned session as seen by a driver: everything delegates to
    the real session except lifecycle — the driver's ``close``/``__exit__``
    must not end a session it did not open."""

    __slots__ = ("_inner",)

    def __init__(self, inner: TelemetrySession):
        self._inner = inner

    def __getattr__(self, name: str):
        return getattr(self._inner, name)

    def close(self) -> None:
        pass

    def __enter__(self) -> "_BorrowedSession":
        return self

    def __exit__(self, *exc) -> None:
        pass


def resolve_telemetry(telemetry: Optional[Telemetry], verbose: bool = False,
                      run: str = "", **meta: Any):
    """The drivers' telemetry entry point.

    * ``telemetry=None, verbose=False`` — the shared no-op session.
    * ``telemetry=None, verbose=True``  — console sink only (the historical
      ``verbose`` prints, now uniform across drivers).
    * a :class:`Telemetry` config — a fresh session; ``verbose=True``
      additionally forces the console sink on (back-compat alias).
    * an already-open :class:`TelemetrySession` (or ``NULL_SESSION``) —
      borrowed: the driver records into it but a driver-side ``close`` is a
      no-op, so one session can observe several runs and the caller decides
      when it ends.
    """
    if isinstance(telemetry, NullSession):
        return telemetry
    if isinstance(telemetry, TelemetrySession):
        return _BorrowedSession(telemetry)
    if telemetry is None:
        if not verbose:
            return NULL_SESSION
        telemetry = Telemetry(console=True)
    if not telemetry.enabled:
        return NULL_SESSION
    if verbose and not telemetry.console:
        telemetry = dataclasses.replace(telemetry, console=True)
    return telemetry.session(run=run, **meta)
