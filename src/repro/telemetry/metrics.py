"""Metrics registry: per-round gauges and run-cumulative counters.

The registry is deliberately host-only and fetch-free: every value it
records arrives as a plain Python number that the drivers *already* pulled
from the device — the fused batched path's single stacked host fetch
(``repro.selection.unpack_fetch``) plus the engine-independent CommMeter
accounting.  Recording metrics therefore adds zero device→host syncs; the
bit-identity contract (telemetry on == telemetry off) holds structurally.

``round_gauges`` maps one driver History record + CommMeter into the
per-round gauge dict the session emits; ``jit_cache_stats`` snapshots the
protocol layer's compiled-program caches (how many distinct round programs
exist, and how often the runner caches hit — jit cache hits mean the round
re-used a compiled program instead of re-tracing).
"""
from __future__ import annotations

from typing import Any, Dict, Optional


class MetricsRegistry:
    """Counters accumulate across the run; gauges hold the latest value.
    Both are plain floats/ints keyed by dotted names."""

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}

    def inc(self, name: str, value: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + value

    def set(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": dict(self.counters), "gauges": dict(self.gauges)}

    def observe_round(self, rec: Dict[str, Any]) -> None:
        """Fold one driver round record into the cumulative counters."""
        self.inc("rounds")
        if rec.get("accepted", True):
            self.inc("rounds_accepted")
        self.inc("detections", int(rec.get("detections", 0)))
        if rec.get("selected_honest"):
            self.inc("honest_selections")


_ROUND_FIELDS = ("selected", "accepted", "detections", "selected_honest",
                 "honest_cluster_exists", "test_acc", "train_loss",
                 "val_losses", "train_losses")


def round_gauges(rec: Dict[str, Any],
                 feeder_depth: Optional[int] = None) -> Dict[str, Any]:
    """Per-round gauges out of a driver History record: validation losses,
    selected cluster, detections/accepted/honesty, the CommMeter float+byte
    deltas (the per-round meter IS the delta — drivers reset it each round)
    and the feeder queue depth.  Values are the Python scalars the drivers
    already fetched; nothing here touches a device array."""
    out: Dict[str, Any] = {}
    for k in _ROUND_FIELDS:
        if k in rec:
            out[k] = rec[k]
    if "comm" in rec:
        out["comm"] = dict(rec["comm"])
    if feeder_depth is not None:
        out["feeder_depth"] = int(feeder_depth)
    return out


def pool_gauges(t0s: Dict[str, int], k: int, lanes: int,
                jobs_done: int, jobs_total: int) -> Dict[str, Any]:
    """Per-pool-block gauges for the job-pool driver's ``pool_block`` event:
    which jobs occupied a lane this block (and each lane's starting round),
    the scanned block length K, the lane count, and queue progress.  Like
    :func:`round_gauges`, strictly host-side — every value is scheduler
    state the driver already holds, so emitting it costs no device sync."""
    return {"jobs": dict(t0s), "k": int(k), "lanes": int(lanes),
            "active": len(t0s), "jobs_done": int(jobs_done),
            "jobs_total": int(jobs_total)}


def jit_cache_stats() -> Dict[str, Any]:
    """Snapshot of the protocol layer's compiled-program caches:

    * ``runner_cache_hits``/``misses`` — the lru-cached runner factories
      (hits = rounds that re-used an existing RoundRunner instead of
      building and re-tracing one);
    * ``runners`` / ``programs`` / ``program_signatures`` — live RoundRunner
      instances, their jitted entry points, and the total compiled-signature
      count across them (``jitted._cache_size``);
    * ``trace_compile_s`` — summed first-call wall time of every jitted
      entry (trace + XLA compile; the runner records it once per program);
    * ``persistent_cache_*`` — JAX's on-disk compilation cache (directory,
      entry count, this process's lookup hits/misses), from
      :func:`repro.core.compile_cache.compile_cache_stats`.

    Purely host-side introspection — safe to call every round."""
    from ..core import engine as _engine
    from ..core import runner as _runner
    from ..core.compile_cache import compile_cache_stats
    stats: Dict[str, Any] = {}
    hits = misses = 0
    for fac in (_runner.protocol_runner, _runner.protocol_accept_runner,
                _engine.splitfed_runner, _engine.splitfed_accept_runner):
        info = fac.cache_info()
        hits += info.hits
        misses += info.misses
    stats["runner_cache_hits"] = hits
    stats["runner_cache_misses"] = misses
    runners = programs = signatures = 0
    compile_s = 0.0
    for r in _runner.live_runners():
        runners += 1
        programs += len(r._jitted)
        for f in r._jitted.values():
            try:
                signatures += f._cache_size()
            except (AttributeError, TypeError):
                pass
        compile_s += sum(r._trace_compile_s.values())
    stats["runners"] = runners
    stats["programs"] = programs
    stats["program_signatures"] = signatures
    stats["trace_compile_s"] = round(compile_s, 6)
    stats.update(compile_cache_stats())
    return stats


def program_census() -> Dict[str, Dict[str, int]]:
    """Deterministic placement/entry breakdown of the compiled programs
    behind :func:`jit_cache_stats`'s aggregate counts: for every live
    RoundRunner, each jitted entry contributes to its ``"{placement}/{entry}"``
    row (programs = jitted entry objects, signatures = compiled shape
    signatures inside each).  The static-analysis layer's compile-count
    budgets (``repro.analysis.budgets``) pin these rows per driver cell —
    a retrace regression shows up as a signature count above baseline."""
    from ..core import runner as _runner
    census: Dict[str, Dict[str, int]] = {}
    for r in _runner.live_runners():
        for which, f in r._jitted.items():
            key = f"{getattr(r, 'placement', '?')}/{which}"
            row = census.setdefault(key, {"programs": 0, "signatures": 0})
            row["programs"] += 1
            try:
                row["signatures"] += f._cache_size()
            except (AttributeError, TypeError):
                pass
    return {k: census[k] for k in sorted(census)}
