"""Span tracer: nested, monotonic-clock phase timing with explicit device
fencing.

A :class:`Tracer` produces :class:`Span` records — name, wall-clock duration
on the monotonic ``time.perf_counter`` clock, nesting path and thread — and
hands each finished span to an ``emit`` callback (the telemetry session's
sink fan-out).  Spans nest per *thread* (the stack lives in
``threading.local``), so the :class:`~repro.data.pipeline.RoundFeeder`'s
producer thread traces its assembly work without interleaving into the main
thread's round spans.

Device attribution is explicit rather than implicit: JAX dispatch is
asynchronous, so the wall-clock interval around ``runner.accept(...)`` only
measures *enqueue* time unless the span waits for the device.  Call
:meth:`Span.fence` with the arrays the phase produced and the span exit runs
``jax.block_until_ready`` on them *before* reading the clock — the device
work is attributed to the phase that launched it, and the following phase
(e.g. the host fetch) measures only its own cost.  Fencing waits for
completion; it performs no device→host data transfer, so enabling telemetry
adds no extra fetches to the batched path.

:class:`Stopwatch` is the module's plain timer helper (the launch scripts'
replacement for non-monotonic ``time.time()`` deltas).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional


class Stopwatch:
    """Monotonic context-manager timer: ``with Stopwatch() as sw: ...`` then
    read ``sw.elapsed`` (seconds on the ``perf_counter`` clock).  The wall
    clock (``time.time``) can step backwards under NTP adjustment; every
    telemetry duration goes through this helper or :class:`Tracer`."""

    def __enter__(self) -> "Stopwatch":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.perf_counter() - self.t0


class Span:
    """One live span.  Created by :meth:`Tracer.span`; used as a context
    manager.  ``fence(arrays)`` registers pytrees whose device computation
    belongs to this span — span exit blocks on them before stopping the
    clock."""

    __slots__ = ("name", "attrs", "_tracer", "_t0", "_fences")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._fences: List[Any] = []

    def fence(self, *arrays: Any) -> None:
        """Attribute the device work producing ``arrays`` (any pytrees) to
        this span: exit calls ``jax.block_until_ready`` on them before the
        duration is read."""
        self._fences.extend(arrays)

    def __enter__(self) -> "Span":
        self._tracer._push(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._fences:
            import jax
            jax.block_until_ready(self._fences)
        dur = time.perf_counter() - self._t0
        path, depth = self._tracer._pop()
        event = {"event": "span", "name": self.name, "path": path,
                 "depth": depth, "dur_s": dur,
                 "thread": threading.current_thread().name}
        if exc_type is not None:
            event["error"] = exc_type.__name__
        event.update(self.attrs)
        self._tracer._emit(event)


class Tracer:
    """Factory for nested spans.  ``emit`` receives one dict per finished
    span (children before parents, since parents exit last).  Thread-safe:
    each thread nests independently."""

    def __init__(self, emit: Callable[[Dict[str, Any]], None]):
        self._emit = emit
        self._local = threading.local()

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _push(self, name: str) -> None:
        self._stack().append(name)

    def _pop(self) -> tuple:
        stack = self._stack()
        path = "/".join(stack)
        stack.pop()
        return path, len(stack)

    def span(self, name: str, **attrs: Any) -> Span:
        return Span(self, name, attrs)


class NullSpan:
    """The disabled tracer's span: every operation is a no-op, so the hot
    loop pays one attribute lookup and one method call per phase."""

    __slots__ = ()

    def fence(self, *arrays: Any) -> None:
        pass

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


NULL_SPAN = NullSpan()


class NullTracer:
    __slots__ = ()

    def span(self, name: str, **attrs: Any) -> NullSpan:
        return NULL_SPAN


NULL_TRACER = NullTracer()
