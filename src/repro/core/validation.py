"""Shared-dataset validation (Section III-C).

The AP never sees raw client data; at the end of a round the *last* client of
each cluster pushes the cut-layer activations of the shared dataset D_o and
the AP finishes the forward pass to obtain the cluster validation loss
l_bar_r.  Cluster selection is argmin over clusters.

``check_handoff`` implements the tamper-resilience mechanism: the first
clients of the next round each transmit g(x_0, gamma_received); the AP
compares them against the activations the selected cluster reported at
validation time — any mismatch exposes a parameter-tampering last client and
triggers a rollback/reselect.

Migration note: the *drivers* no longer call ``select_cluster`` /
``check_handoff`` directly — cluster acceptance (score -> rank -> verify ->
commit) lives in the pluggable ``repro.selection`` subsystem, which either
compiles the cascade into the round program (the batched engines) or runs
the host reference selector (``repro.selection.select_host``, which calls
:func:`check_handoff` for its verify stage).  Both functions remain public
for external callers: ``select_cluster`` is the argmin policy's rule on host
data, and ``check_handoff`` the reference handoff comparison.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .split import SplitModule

Pytree = Any


@partial(jax.jit, static_argnums=(0,))
def validation_loss(module: SplitModule, gamma: Pytree, phi: Pytree,
                    x0: jnp.ndarray, y0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (loss, cut-activations).  The activations are what the last
    client actually transmits — kept so the AP can cross-check handoffs."""
    acts = module.client_forward(gamma, x0)
    loss = module.ap_loss(phi, acts, y0)
    return loss, acts


def select_cluster(losses: Sequence[float]) -> int:
    """argmin_r l_bar_r (ties broken towards the lower index).  The losses
    are host data by the time selection happens, so this is a plain numpy
    argmin — it used to dispatch (and re-trace) a jitted ``jnp.argmin`` on a
    Python list per call; the device-side selection path lives in the
    compiled round programs (``repro.selection``)."""
    return int(np.argmin(np.asarray(losses)))


@partial(jax.jit, static_argnums=(0,))
def handoff_activations(module: SplitModule, gamma: Pytree, x0: jnp.ndarray) -> jnp.ndarray:
    """g(x_0, gamma_received) transmitted by a first client before training."""
    return module.client_forward(gamma, x0)


@jax.jit
def _handoff_max_distance(ref: jnp.ndarray, stacked: jnp.ndarray) -> jnp.ndarray:
    """max_k ||recv_k - ref|| / ||ref|| over the stacked (K, ...) receipts,
    reduced in one device program."""
    ref = ref.astype(jnp.float32)
    denom = jnp.maximum(jnp.linalg.norm(ref.ravel()), 1e-12)
    diffs = (stacked.astype(jnp.float32) - ref[None]).reshape(stacked.shape[0], -1)
    return jnp.max(jnp.linalg.norm(diffs, axis=1)) / denom


def check_handoff(reference_acts: jnp.ndarray, received: Sequence[jnp.ndarray],
                  tol: float = 1e-4) -> Tuple[bool, float]:
    """AP-side comparison.  ``reference_acts`` are the validation-time
    activations from the selected cluster's last client; ``received`` are the
    next-round first clients' transmissions.  Honest handoff => all equal.

    The K receipts are stacked and reduced in a single jitted device op —
    one host sync for the whole check instead of one per first client.

    Returns (ok, max_distance)."""
    received = list(received)
    if not received:
        return True, 0.0
    max_d = float(_handoff_max_distance(reference_acts, jnp.stack(received)))
    return max_d <= tol, max_d
