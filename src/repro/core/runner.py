"""Device-placement-aware Pigeon round runner.

Pigeon-SL's global round is embarrassingly parallel across the R = N + 1
clusters: every cluster trains from the same theta^t, validates on the shared
set D_o, and only the argmin-loss winner survives.  Before this module the
repo carried the round in two divergent places — the protocol-level batched
engine (``core/engine.py``, vmap over clusters on one device) and the
launch-level pod-sharded step (``launch/steps.py``, shard_map over the "pod"
mesh axis) — which duplicated the train + validate + argmin + broadcast
program and could not share fixes.

:class:`RoundRunner` is the single source of truth.  A :class:`RoundSpec`
supplies the two pure per-cluster programs (``train_cluster`` and
``validate``); the runner compiles the cluster-parallel round under a
pluggable *placement policy*:

  * ``placement="vmap"``    — ``jax.vmap`` over the cluster axis, one device
                              (the protocol engine's historical strategy);
  * ``placement="sharded"`` — the cluster axis laid over a mesh axis
                              (default ``"pod"``) via ``shard_map``; each
                              shard runs a vmap over its local cluster slice,
                              so R need not equal the device count (any mesh
                              whose cluster-axis size divides R works).

Both placements run the *same* ``cluster_map`` body, so they are numerically
equivalent by construction — the CPU equivalence suite
(``tests/test_runner.py``) checks selection, losses and CommMeter history
against the sequential oracle under a forced 8-virtual-device host mesh.

Consumers:

  * ``core/engine.py`` binds :func:`protocol_round_spec` (client-chain scan +
    ``AttackVec`` threat-model lanes + shared-set validation) and uses
    :meth:`RoundRunner.candidates` — selection stays on the host because the
    tamper-resilient handoff check (Section III-C) may reject the argmin.
  * ``launch/steps.py`` binds a ``Model``-level spec and uses
    :meth:`RoundRunner.round_fn` — the full round (selection + winner
    broadcast inside the compiled program), lowered under GSPMD/manual pod
    sharding by the dry-run driver.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.5: public API, new kwargs
    _shard_map = jax.shard_map          # type: ignore[attr-defined]
    _SHARD_MAP_LEGACY = False
except AttributeError:                  # jax 0.4.x: experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_LEGACY = True

Pytree = Any

PLACEMENTS = ("vmap", "sharded")


def check_placement(placement: str) -> None:
    if placement not in PLACEMENTS:
        raise ValueError(f"placement={placement!r} must be one of {PLACEMENTS}")


# ---------------------------------------------------------------------------
# shared primitives
# ---------------------------------------------------------------------------

def onehot_select(stacked: Pytree, sel: jnp.ndarray) -> Pytree:
    """Pick index ``sel`` along each leaf's leading axis via a one-hot
    contraction: lowers to one masked reduction per leaf instead of the
    gather+full-replicate path GSPMD emits for dynamic indexing.  The mask is
    applied with ``jnp.where`` rather than multiplication so Inf/NaN in
    *unselected* slots (e.g. a diverged malicious cluster) cannot poison the
    selected values through ``0 * inf = nan``."""

    def pick(x):
        mask = (jnp.arange(x.shape[0]) == sel).reshape((-1,) + (1,) * (x.ndim - 1))
        masked = jnp.where(mask, x.astype(jnp.float32), 0.0)
        return jnp.sum(masked, axis=0).astype(x.dtype)

    return jax.tree.map(pick, stacked)


def broadcast_winner(winner: Pytree, stacked: Pytree) -> Pytree:
    """The paper's winner hand-off: every cluster slot of the next round
    starts from the selected cluster's parameters."""
    return jax.tree.map(
        lambda w, full: jnp.broadcast_to(w[None], full.shape).astype(full.dtype),
        winner, stacked)


@lru_cache(maxsize=None)
def cluster_mesh(r: int, max_devices: Optional[int] = None) -> Mesh:
    """1-D ("pod",) mesh over the largest divisor of R that fits the
    available devices — every shard then carries an equal R_local slice of
    the cluster axis (R_local = 1 when R <= device count)."""
    devs = jax.devices()
    n = min(len(devs), max_devices if max_devices else len(devs))
    while r % n:
        n -= 1
    return Mesh(np.array(devs[:n]), ("pod",))


def _apply_shard_map(fn, mesh: Mesh, in_specs, out_specs, manual_axis: str):
    """Version shim: jax 0.4.x experimental shard_map (check_rep/auto) vs the
    jax >= 0.5 public API (check_vma/axis_names).  ``manual_axis`` is the
    only manually-mapped axis; any other mesh axes stay GSPMD-auto."""
    if _SHARD_MAP_LEGACY:
        auto = frozenset(mesh.axis_names) - {manual_axis}
        return _shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False, auto=auto)
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False, axis_names={manual_axis})


# ---------------------------------------------------------------------------
# the round program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """The two pure per-cluster programs of one Pigeon round.

    ``train_cluster(params, inputs) -> (params', train_aux)`` — one cluster's
    whole training phase (for the protocol engine: the within-cluster client
    chain; for the launch layer: one SPMD train step).

    ``validate(params', val) -> (vloss, val_aux)`` — the shared-set
    validation forward (Section III-C).  ``val_aux`` carries whatever the
    consumer needs alongside the loss (the protocol engine keeps the cut
    activations for the tamper check; the launch spec returns None).
    """
    train_cluster: Callable[[Pytree, Any], Tuple[Pytree, Any]]
    validate: Callable[[Pytree, Any], Tuple[jnp.ndarray, Any]]


def cluster_map(spec: RoundSpec, params: Pytree, inputs: Pytree, val: Pytree,
                params_stacked: bool = False):
    """Train + validate every cluster on the leading axis of ``inputs`` —
    THE one copy of the Pigeon round math, shared by both placements (and by
    the multi-seed sweep, which vmaps it once more over seeds).

    Returns ``(params_R, train_aux_R, vlosses_R, val_aux_R)``.  When
    ``params_stacked`` the params already carry the leading cluster axis
    (each cluster trains its own replica, the launch-layer layout); otherwise
    a single params pytree is broadcast into every cluster (the protocol
    layout, where all clusters start from theta^t)."""

    def one(params_r, inputs_r):
        new_p, aux = spec.train_cluster(params_r, inputs_r)
        vloss, vaux = spec.validate(new_p, val)
        return new_p, aux, vloss, vaux

    return jax.vmap(one, in_axes=(0 if params_stacked else None, 0))(params, inputs)


class RoundRunner:
    """Compiles a :class:`RoundSpec` under a placement policy.

    Two entry levels:

    * :meth:`candidates_fn` / :meth:`candidates` — all R candidate outcomes,
      selection left to the caller (the protocol drivers' host-side
      argmin + tamper-check loop).
    * :meth:`round_fn` / :meth:`round` — the full round with argmin selection
      and winner broadcast inside the compiled program (the launch-layer
      ``pigeon_round_step`` contract: returns ``(rebro, vlosses, sel)``).

    ``mesh`` is only consulted by the sharded placement; when omitted a 1-D
    host mesh sized to the largest divisor of R is built per call shape
    (:func:`cluster_mesh`).  ``cluster_axis`` names the mesh axis carrying
    cluster parallelism; other axes stay GSPMD-auto, so the launch layer's
    ("pod", "data", "model") meshes keep their data/model sharding."""

    def __init__(self, spec: RoundSpec, *, placement: str = "vmap",
                 mesh: Optional[Mesh] = None, cluster_axis: str = "pod",
                 params_stacked: bool = False):
        check_placement(placement)
        self.spec = spec
        self.placement = placement
        self.mesh = mesh
        self.cluster_axis = cluster_axis
        self.params_stacked = params_stacked
        self._jitted: dict = {}

    # -- pure, traceable bodies (jit / lower externally) --------------------

    def candidates_fn(self) -> Callable:
        """(params, inputs, val) -> (params_R, train_aux_R, vlosses_R,
        val_aux_R), all with leading cluster axis R."""
        if self.placement == "vmap":
            return lambda params, inputs, val: cluster_map(
                self.spec, params, inputs, val, self.params_stacked)
        return lambda params, inputs, val: self._sharded(
            params, inputs, val, select=False)

    def round_fn(self) -> Callable:
        """(params, inputs, val) -> (rebro_params_R, vlosses_R, sel): the
        full round with in-program argmin selection + winner broadcast."""
        if self.placement == "vmap":
            def round_body(params, inputs, val):
                new_p, _, vlosses, _ = cluster_map(
                    self.spec, params, inputs, val, self.params_stacked)
                sel = jnp.argmin(vlosses)
                rebro = broadcast_winner(onehot_select(new_p, sel), new_p)
                return rebro, vlosses, sel
            return round_body
        return lambda params, inputs, val: self._sharded(
            params, inputs, val, select=True)

    # -- sharded placement --------------------------------------------------

    def _sharded(self, params, inputs, val, select: bool):
        ax = self.cluster_axis
        r = jax.tree.leaves(inputs)[0].shape[0]
        mesh = self.mesh if self.mesh is not None else cluster_mesh(r)
        if r % mesh.shape[ax]:
            raise ValueError(f"R={r} not divisible by mesh axis "
                             f"{ax!r}={mesh.shape[ax]}")

        def per_shard(params_s, inputs_s, val_s):
            # params_s: the local R_local slice (stacked) or the full
            # replicated pytree; inputs_s: the local cluster slice.
            new_p, aux, vloss, vaux = cluster_map(
                self.spec, params_s, inputs_s, val_s, self.params_stacked)
            if not select:
                return new_p, aux, vloss, vaux
            losses = jax.lax.all_gather(vloss, ax, tiled=True)       # (R,)
            sel = jnp.argmin(losses)
            r_local = vloss.shape[0]
            mine = (jax.lax.axis_index(ax) * r_local
                    + jnp.arange(r_local)) == sel

            def pick(x):
                mask = mine.reshape((-1,) + (1,) * (x.ndim - 1))
                local = jnp.sum(jnp.where(mask, x.astype(jnp.float32), 0.0),
                                axis=0)
                return jax.lax.psum(local, ax).astype(x.dtype)

            rebro = broadcast_winner(jax.tree.map(pick, new_p), new_p)
            return rebro, losses, sel

        p_spec = P(ax) if self.params_stacked else P()
        in_specs = (p_spec, P(ax), P())
        out_specs = ((P(ax), P(), P()) if select
                     else (P(ax), P(ax), P(ax), P(ax)))
        fn = _apply_shard_map(per_shard, mesh, in_specs, out_specs, ax)
        return fn(params, inputs, val)

    # -- jitted convenience entry points ------------------------------------

    def _compiled(self, which: str) -> Callable:
        fn = self._jitted.get(which)
        if fn is None:
            body = self.candidates_fn() if which == "candidates" else self.round_fn()
            fn = jax.jit(body)
            self._jitted[which] = fn
        return fn

    def candidates(self, params, inputs, val):
        return self._compiled("candidates")(params, inputs, val)

    def round(self, params, inputs, val):
        return self._compiled("round")(params, inputs, val)


# ---------------------------------------------------------------------------
# the protocol-level binding (SplitModule + AttackVec lanes)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def protocol_round_spec(module, lr: float) -> RoundSpec:
    """Pigeon per-cluster programs over a ``SplitModule``: the within-cluster
    client-chain scan with the AttackVec threat-model lanes from the
    adversary subsystem (``inputs = (xs, ys, avec, keys)``, every leaf with
    leading axis M_bar), and shared-set validation returning the cut
    activations the tamper check compares against (``val = (x0, y0)``)."""
    from .split import client_update_vec_impl

    def train_cluster(theta, inputs):
        xs_c, ys_c, av_c, keys_c = inputs
        gamma, phi = theta

        def per_client(carry, inp):
            g, p = carry
            x, y, av, k = inp
            g, p, loss = client_update_vec_impl(module, av, g, p, (x, y), lr, k)
            return (g, p), loss

        (g, p), losses = jax.lax.scan(per_client, (gamma, phi),
                                      (xs_c, ys_c, av_c, keys_c))
        return (g, p), losses

    def validate(theta, val):
        g, p = theta
        x0, y0 = val
        acts = module.client_forward(g, x0)
        return module.ap_loss(p, acts, y0), acts

    return RoundSpec(train_cluster, validate)


@lru_cache(maxsize=None)
def protocol_runner(module, lr: float, placement: str = "vmap") -> RoundRunner:
    """Cached per (module, lr, placement) so every round reuses one compiled
    program — the protocol layout (theta broadcast into all clusters)."""
    return RoundRunner(protocol_round_spec(module, lr), placement=placement)
