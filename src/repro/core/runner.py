"""Device-placement-aware Pigeon round runner.

Pigeon-SL's global round is embarrassingly parallel across the R = N + 1
clusters: every cluster trains from the same theta^t, validates on the shared
set D_o, and only the argmin-loss winner survives.  Before this module the
repo carried the round in two divergent places — the protocol-level batched
engine (``core/engine.py``, vmap over clusters on one device) and the
launch-level pod-sharded step (``launch/steps.py``, shard_map over the "pod"
mesh axis) — which duplicated the train + validate + argmin + broadcast
program and could not share fixes.

:class:`RoundRunner` is the single source of truth.  A :class:`RoundSpec`
supplies the pure per-cluster programs (``train_cluster``, an optional
``combine`` fan-in — SplitFed's FedAvg — and ``validate``); the runner
compiles the cluster-parallel round under a pluggable *placement policy*:

  * ``placement="vmap"``    — ``jax.vmap`` over the cluster axis, one device
                              (the protocol engine's historical strategy);
  * ``placement="sharded"`` — the cluster axis laid over a mesh axis
                              (default ``"pod"``) via ``shard_map``; each
                              shard runs a vmap over its local cluster slice,
                              so R need not equal the device count (any mesh
                              whose cluster-axis size divides R works).

A third entry level, :meth:`RoundRunner.sweep`, runs S independent protocol
replicas (the multi-seed sweep) with per-seed argmin selection on device —
under vmap a second seed-level ``jax.vmap``, under the sharded placement a
2-D ``(seed, cluster)`` mesh (default axes ``("seed", "pod")``) so the
S x R replica grid lays out over real devices.

Both placements run the *same* ``cluster_map`` body, so they are numerically
equivalent by construction — the CPU equivalence suite
(``tests/test_runner.py``) checks selection, losses and CommMeter history
against the sequential oracle under a forced 8-virtual-device host mesh.

Selection is pluggable: a :class:`~repro.selection.SelectionPolicy` bound via
the runner's ``select=`` hook supplies the score/eligibility stages wherever
a winner is chosen inside the compiled program — :meth:`RoundRunner.round_fn`
(launch layer), :meth:`RoundRunner.sweep` (per-seed selection), and
:meth:`RoundRunner.accept`, the fused score -> rank -> verify -> commit
cascade (``repro.selection.cascade``) that replaced the protocol drivers'
host-side selection loop on the default batched path: candidate ranks as
data, handoff distances via the ``kernels/tamper_check`` Pallas kernel,
rejection as a ``jnp.where`` mask, one stacked host fetch per round.

Consumers:

  * ``core/engine.py`` binds :func:`protocol_round_spec` (client-chain scan +
    ``AttackVec`` threat-model lanes + shared-set validation) and uses
    :meth:`RoundRunner.accept` on the default path; the host-side reference
    cascade (:meth:`RoundRunner.candidates` + ``repro.selection.select_host``)
    remains for the sequential oracle and param-tamper threat models, whose
    handoff tampering consumes the protocol key per visited candidate.
  * ``launch/steps.py`` binds a ``Model``-level spec and uses
    :meth:`RoundRunner.round_fn` — the full round (policy selection + winner
    broadcast inside the compiled program), lowered under GSPMD/manual pod
    sharding by the dry-run driver.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from functools import lru_cache
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.5: public API, new kwargs
    _shard_map = jax.shard_map          # type: ignore[attr-defined]
    _SHARD_MAP_LEGACY = False
except AttributeError:                  # jax 0.4.x: experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_LEGACY = True

Pytree = Any

PLACEMENTS = ("vmap", "sharded")

# Live-runner registry for telemetry introspection
# (``repro.telemetry.metrics.jit_cache_stats``): weak references only, so
# registration never extends a runner's lifetime past its cache entry.
_LIVE_RUNNERS: "weakref.WeakSet" = weakref.WeakSet()


def live_runners() -> list:
    """The RoundRunner instances currently alive (telemetry introspection)."""
    return list(_LIVE_RUNNERS)


def check_placement(placement: str) -> None:
    if placement not in PLACEMENTS:
        raise ValueError(f"placement={placement!r} must be one of {PLACEMENTS}")


# ---------------------------------------------------------------------------
# shared primitives
# ---------------------------------------------------------------------------

def onehot_select(stacked: Pytree, sel: jnp.ndarray) -> Pytree:
    """Pick index ``sel`` along each leaf's leading axis via a one-hot
    contraction: lowers to one masked reduction per leaf instead of the
    gather+full-replicate path GSPMD emits for dynamic indexing.  The mask is
    applied with ``jnp.where`` rather than multiplication so Inf/NaN in
    *unselected* slots (e.g. a diverged malicious cluster) cannot poison the
    selected values through ``0 * inf = nan``."""

    def pick(x):
        mask = (jnp.arange(x.shape[0]) == sel).reshape((-1,) + (1,) * (x.ndim - 1))
        masked = jnp.where(mask, x.astype(jnp.float32), jnp.float32(0.0))
        return jnp.sum(masked, axis=0).astype(x.dtype)

    return jax.tree.map(pick, stacked)


def broadcast_winner(winner: Pytree, stacked: Pytree) -> Pytree:
    """The paper's winner hand-off: every cluster slot of the next round
    starts from the selected cluster's parameters."""
    return jax.tree.map(
        lambda w, full: jnp.broadcast_to(w[None], full.shape).astype(full.dtype),
        winner, stacked)


@lru_cache(maxsize=None)
def cluster_mesh(r: int, max_devices: Optional[int] = None) -> Mesh:
    """1-D ("pod",) mesh over the largest divisor of R that fits the
    available devices — every shard then carries an equal R_local slice of
    the cluster axis (R_local = 1 when R <= device count)."""
    devs = jax.devices()
    n = min(len(devs), max_devices if max_devices else len(devs))
    return Mesh(np.array(devs[:_largest_divisor(r, n)]), ("pod",))


def _largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1 always: a cap of
    zero or below degrades to the trivial divisor instead of dividing by
    zero — prime R on a 1-device host must still yield a valid mesh)."""
    d = max(1, min(n, cap))
    while n % d:
        d -= 1
    return d


@lru_cache(maxsize=None)
def sweep_mesh(s: int, r: int, max_devices: Optional[int] = None) -> Mesh:
    """2-D ("seed", "pod") mesh for the multi-seed sweep: the factorisation
    of the available devices into (divisor of S) x (divisor of R) that covers
    the most devices, so the S x R replica grid spreads as wide as the
    hardware allows (ties resolved toward the wider cluster axis — the
    cluster dimension is the paper's dominant parallelism).  The ``sn=1``
    seed never loses to worse factorisations: when neither S nor R factor
    against the device count (both prime, say), the result degrades to the
    widest 1-D cluster mesh (``1 x _largest_divisor(r, n)``), never below
    it."""
    devs = jax.devices()
    n = max(1, min(len(devs), max_devices if max_devices else len(devs)))
    best_s, best_r = 1, _largest_divisor(r, n)      # widest 1-D fallback
    for sn in range(1, min(s, n) + 1):
        if s % sn:
            continue
        rn = _largest_divisor(r, n // sn)
        if sn * rn > best_s * best_r or (sn * rn == best_s * best_r
                                         and rn > best_r):
            best_s, best_r = sn, rn
    return Mesh(np.array(devs[: best_s * best_r]).reshape(best_s, best_r),
                ("seed", "pod"))


def _normalize_manual_axes(manual_axes) -> frozenset:
    return frozenset((manual_axes,) if isinstance(manual_axes, str)
                     else manual_axes)


def _auto_axes(mesh: Mesh, manual_axes) -> list:
    manual = _normalize_manual_axes(manual_axes)
    return [a for a in mesh.axis_names if a not in manual]


def _apply_shard_map(fn, mesh: Mesh, in_specs, out_specs, manual_axes):
    """Version shim: jax 0.4.x experimental shard_map (check_rep/auto) vs the
    jax >= 0.5 public API (check_vma/axis_names).  ``manual_axes`` are the
    manually-mapped axes; any other mesh axes stay GSPMD-auto."""
    manual = _normalize_manual_axes(manual_axes)
    if _SHARD_MAP_LEGACY:
        auto = frozenset(mesh.axis_names) - manual
        return _shard_map(fn, mesh, in_specs=in_specs, out_specs=out_specs,
                          check_rep=False, auto=auto)
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False, axis_names=set(manual))


def backend_supports_partial_auto(mesh: Mesh, manual_axes) -> bool:
    """Partial-auto shard_map (manual cluster axis + GSPMD-auto data/model
    axes) lowers fine everywhere but cannot *execute* on the XLA CPU backend
    when the auto axes span more than one device — CPU has no PartitionId
    under SPMD, so XLA crashes with an inscrutable error at run time."""
    auto = _auto_axes(mesh, manual_axes)
    auto_size = int(np.prod([mesh.shape[a] for a in auto], dtype=np.int64))
    if auto_size <= 1:
        return True
    return not all(d.platform == "cpu" for d in mesh.devices.flat)


def check_partial_auto_backend(mesh: Mesh, manual_axes) -> None:
    """Raise a clear error instead of letting XLA crash (ROADMAP open item:
    CPU pods + partial-auto shard_map).  Called on the *execution* entry
    points only — dry-run lowering/compilation of the same program is
    supported on every backend and must stay gate-free."""
    if backend_supports_partial_auto(mesh, manual_axes):
        return
    auto = _auto_axes(mesh, manual_axes)
    raise RuntimeError(
        f"partial-auto shard_map cannot execute on the CPU backend: mesh "
        f"{dict(mesh.shape)} has GSPMD-auto axes {auto} spanning "
        f"{np.prod([mesh.shape[a] for a in auto])} devices, and XLA CPU has "
        f"no PartitionId under SPMD.  Use a fully-manual 1-D cluster mesh on "
        f"CPU (mesh=None lets the runner build one), or run on TPU/GPU; "
        f"dry-run lowering of this program on CPU remains supported.")


# ---------------------------------------------------------------------------
# the round program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundSpec:
    """The pure per-cluster programs of one Pigeon round.

    ``train_cluster(params, inputs) -> (params', train_aux)`` — one cluster's
    whole training phase (for the protocol engine: the within-cluster client
    chain; for SplitFed: all clients in parallel, leaving a leading client
    axis on ``params'``; for the launch layer: one SPMD train step).

    ``combine(params') -> cluster_params`` — optional fan-in applied between
    training and validation, for round families whose cluster model is an
    *aggregate* of per-client results rather than the chain's final state:
    SplitFed binds FedAvg (mean over the client axis ``train_cluster`` left
    on its output).  ``None`` (the default) means ``train_cluster`` already
    returns the cluster model.

    ``validate(cluster_params, val) -> (vloss, val_aux)`` — the shared-set
    validation forward (Section III-C).  ``val_aux`` carries whatever the
    consumer needs alongside the loss (the protocol engine keeps the cut
    activations for the tamper check; the launch spec returns None).

    The optional selection hooks feed the pluggable policies
    (``repro.selection``); a policy whose feature needs the bound spec cannot
    satisfy is rejected at program-build time:

    ``validate_sharded(cluster_params, val, k) -> (vloss, (k',) shard
    losses, val_aux)`` — shared-set validation split into (up to) ``k``
    equal D_o shards, for the median-of-means family of scores.

    ``handoff_acts(cluster_params, val) -> acts`` — the re-transmission a
    next-round first client would produce from the handed-off parameters;
    the fused verify stage compares it against ``val_aux`` with the
    ``kernels/tamper_check`` distance.

    ``train_summary(stacked_train_aux) -> (R,)`` — per-cluster train metric
    for the drivers' single History fetch (protocol: mean client loss).

    ``message_stats(stacked_train_aux) -> (R, M_bar, S)`` — per-client
    transmitted-message statistics for anomaly-scoring policies (requires a
    ``with_stats`` train program).
    """
    train_cluster: Callable[[Pytree, Any], Tuple[Pytree, Any]]
    validate: Callable[[Pytree, Any], Tuple[jnp.ndarray, Any]]
    combine: Optional[Callable[[Pytree], Pytree]] = None
    validate_sharded: Optional[Callable[[Pytree, Any, int],
                                        Tuple[jnp.ndarray, jnp.ndarray, Any]]] = None
    handoff_acts: Optional[Callable[[Pytree, Any], jnp.ndarray]] = None
    train_summary: Optional[Callable[[Any], jnp.ndarray]] = None
    message_stats: Optional[Callable[[Any], jnp.ndarray]] = None


@dataclasses.dataclass(frozen=True)
class VerifyConfig:
    """The fused cascade's verification stage: compare each candidate's
    handoff transmission against its validation-time activations
    (``kernels/tamper_check``) and reject candidates beyond ``tol``.

    ``recompute`` controls where the transmission comes from: True re-derives
    it from the handed-off parameters (``RoundSpec.handoff_acts`` — needed
    whenever something inside the program could perturb the handoff); False
    reuses the validation-time activations directly.  The protocol drivers'
    fused path runs with False: its precondition (no param-tamper families —
    those pin selection to the host cascade) makes the re-transmission equal
    to the validation activations *by construction*, so recomputing R client
    forwards per round would only confirm an identity.  The masked cascade,
    kernel distance and Table I re-transmission accounting stay live either
    way."""
    enabled: bool = True
    tol: float = 1e-4
    recompute: bool = True


def cluster_map(spec: RoundSpec, params: Pytree, inputs: Pytree, val: Pytree,
                params_stacked: bool = False):
    """Train + (combine +) validate every cluster on the leading axis of
    ``inputs`` — THE one copy of the Pigeon round math, shared by both
    placements (and by the multi-seed sweep, which vmaps it once more over
    seeds).

    Returns ``(params_R, train_aux_R, vlosses_R, val_aux_R)``.  When
    ``params_stacked`` the params already carry the leading cluster axis
    (each cluster trains its own replica, the launch-layer layout); otherwise
    a single params pytree is broadcast into every cluster (the protocol
    layout, where all clusters start from theta^t)."""

    def one(params_r, inputs_r):
        new_p, aux = spec.train_cluster(params_r, inputs_r)
        if spec.combine is not None:
            new_p = spec.combine(new_p)
        vloss, vaux = spec.validate(new_p, val)
        return new_p, aux, vloss, vaux

    return jax.vmap(one, in_axes=(0 if params_stacked else None, 0))(params, inputs)


def select_map(spec: RoundSpec, policy, params: Pytree, inputs: Pytree,
               val: Pytree, params_stacked: bool = False):
    """:func:`cluster_map` + the selection features ``policy`` declares it
    needs: ``(params_R, train_aux_R, vlosses_R, val_aux_R, shard_losses)``
    where ``shard_losses`` is ``(R, K)`` (via the spec's ``validate_sharded``
    hook) or None.  The default argmin policy takes the plain
    :func:`cluster_map` path, so its round program is unchanged."""
    if policy.shard_count <= 0:
        new_p, aux, vloss, vaux = cluster_map(spec, params, inputs, val,
                                              params_stacked)
        return new_p, aux, vloss, vaux, None
    if spec.validate_sharded is None:
        raise ValueError(f"selection policy {policy.name!r} needs sharded "
                         f"validation, which this RoundSpec does not provide")

    def one(params_r, inputs_r):
        new_p, aux = spec.train_cluster(params_r, inputs_r)
        if spec.combine is not None:
            new_p = spec.combine(new_p)
        vloss, shard_l, vaux = spec.validate_sharded(new_p, val,
                                                     policy.shard_count)
        return new_p, aux, vloss, vaux, shard_l

    return jax.vmap(one, in_axes=(0 if params_stacked else None, 0))(params, inputs)


def policy_context(spec: RoundSpec, policy, aux, vlosses, shard_losses):
    """Assemble the in-program :class:`~repro.selection.ScoreContext` —
    features must already be gathered across the full cluster axis (the
    sharded placement all-gathers them first), so policy stages stay pure
    jnp with no collectives."""
    from ..selection import ScoreContext
    stats = None
    if policy.needs_message_stats:
        if spec.message_stats is None:
            raise ValueError(f"selection policy {policy.name!r} needs "
                             f"transmitted-message statistics, which this "
                             f"RoundSpec does not surface")
        stats = spec.message_stats(aux)
    return ScoreContext(vlosses=vlosses, shard_losses=shard_losses,
                        message_stats=stats)


def policy_scores(policy, ctx):
    """(scores, eligibility) with the all-ineligible fallback applied."""
    scores = policy.score(ctx).astype(jnp.float32)
    elig = policy.eligible(ctx, scores)
    elig = jnp.where(jnp.any(elig), elig, jnp.ones_like(elig))
    return scores, elig


def masked_argmin(scores: jnp.ndarray, elig: jnp.ndarray) -> jnp.ndarray:
    """The one copy of the in-program winner rule (ineligible candidates
    sentinel to +inf) — vmap, sharded and sweep placements all call this, so
    their documented bit-for-bit agreement cannot drift."""
    return jnp.argmin(jnp.where(elig, scores,
                                jnp.float32(jnp.inf))).astype(jnp.int32)


def policy_choose(spec: RoundSpec, policy, aux, vlosses, shard_losses):
    """In-program winner index under a policy: masked argmin over scores."""
    ctx = policy_context(spec, policy, aux, vlosses, shard_losses)
    scores, elig = policy_scores(policy, ctx)
    return masked_argmin(scores, elig)


def _spec_train_summary(spec: RoundSpec, aux, vlosses):
    if spec.train_summary is None:
        return jnp.zeros_like(vlosses, dtype=jnp.float32)
    return spec.train_summary(aux).astype(jnp.float32)


def sweep_map(spec: RoundSpec, params: Pytree, inputs: Pytree, val: Pytree,
              params_stacked: bool = False, policy=None):
    """S independent protocol replicas of one global round: per seed, run
    :func:`select_map`, select the policy-winning cluster (default: argmin
    validation loss) and carry the winner forward.  ``params`` leaves lead
    with the seed axis (plus a cluster axis when ``params_stacked``);
    ``inputs`` leaves with ``(seed, cluster)``.  Returns
    ``(winner_params_S, train_aux_SR, vlosses_SR, sel_S)`` — the same
    arithmetic (masked-f32 one-hot contraction) the sharded placement
    reduces with ``psum``, so the two placements agree bit-for-bit."""
    from ..selection import ARGMIN
    policy = ARGMIN if policy is None else policy
    new_p, aux, vlosses, _, shard_l = jax.vmap(
        lambda p, i: select_map(spec, policy, p, i, val, params_stacked)
    )(params, inputs)
    sels = jax.vmap(
        lambda a, vl, sl: policy_choose(spec, policy, a, vl, sl),
        in_axes=(0, 0, None if shard_l is None else 0))(aux, vlosses, shard_l)
    winners = jax.vmap(onehot_select)(new_p, sels)
    return winners, aux, vlosses, sels


class RoundRunner:
    """Compiles a :class:`RoundSpec` under a placement policy.

    Two entry levels:

    * :meth:`candidates_fn` / :meth:`candidates` — all R candidate outcomes,
      selection left to the caller (the host-side reference cascade in
      ``repro.selection.selector`` — the sequential oracle and the
      param-tamper fallback).
    * :meth:`accept_fn` / :meth:`accept` — the fused score -> rank -> verify
      -> commit cascade inside the compiled program: policy scores, masked
      rank walk, per-candidate handoff verification via the
      ``kernels/tamper_check`` distance, winner commit (or rollback when
      every candidate fails), one stacked host fetch
      (``(vlosses, train_summary, selected, detections, accepted)``).
      The protocol drivers' default batched path.
    * :meth:`round_fn` / :meth:`round` — the full round with policy selection
      and winner broadcast inside the compiled program (the launch-layer
      ``pigeon_round_step`` contract: returns ``(rebro, vlosses, sel)``).
    * :meth:`sweep_fn` / :meth:`sweep` — S whole protocol replicas with
      per-seed policy selection on device; the sharded placement lays the
      S x R replica grid over a 2-D ``(seed_axis, cluster_axis)`` mesh.

    ``select`` binds a :class:`~repro.selection.SelectionPolicy` (default:
    the paper's argmin); ``verify`` configures :meth:`accept`'s tamper-check
    stage.

    ``mesh`` is only consulted by the sharded placement; when omitted a 1-D
    host mesh sized to the largest divisor of R (:func:`cluster_mesh`) — or,
    for :meth:`sweep`, the widest 2-D ``(seed, pod)`` factorisation
    (:func:`sweep_mesh`) — is built per call shape.  ``cluster_axis`` /
    ``seed_axis`` name the mesh axes carrying cluster / replica parallelism;
    other axes stay GSPMD-auto, so the launch layer's ("pod", "data",
    "model") meshes keep their data/model sharding.  The jitted execution
    entries gate the partial-auto CPU combination
    (:func:`check_partial_auto_backend`) with a clear error instead of the
    XLA crash; the ``*_fn`` bodies stay gate-free for dry-run lowering."""

    def __init__(self, spec: RoundSpec, *, placement: str = "vmap",
                 mesh: Optional[Mesh] = None, cluster_axis: str = "pod",
                 seed_axis: str = "seed", params_stacked: bool = False,
                 select=None, verify: Optional[VerifyConfig] = None):
        from ..selection import ARGMIN
        check_placement(placement)
        self.spec = spec
        self.placement = placement
        self.mesh = mesh
        self.cluster_axis = cluster_axis
        self.seed_axis = seed_axis
        self.params_stacked = params_stacked
        self.select = ARGMIN if select is None else select
        self.verify = VerifyConfig() if verify is None else verify
        self._jitted: dict = {}
        # first-call wall time per jitted entry (trace + XLA compile +
        # first dispatch), read by telemetry's jit_cache_stats
        self._trace_compile_s: dict = {}
        _LIVE_RUNNERS.add(self)

    # -- pure, traceable bodies (jit / lower externally) --------------------

    def candidates_fn(self) -> Callable:
        """(params, inputs, val) -> (params_R, train_aux_R, vlosses_R,
        val_aux_R), all with leading cluster axis R."""
        if self.placement == "vmap":
            return lambda params, inputs, val: cluster_map(
                self.spec, params, inputs, val, self.params_stacked)
        return lambda params, inputs, val: self._sharded(
            params, inputs, val, select=False)

    def round_fn(self) -> Callable:
        """(params, inputs, val) -> (rebro_params_R, vlosses_R, sel): the
        full round with in-program policy selection + winner broadcast."""
        if self.placement == "vmap":
            def round_body(params, inputs, val):
                new_p, aux, vlosses, _, shard_l = select_map(
                    self.spec, self.select, params, inputs, val,
                    self.params_stacked)
                sel = policy_choose(self.spec, self.select, aux, vlosses,
                                    shard_l)
                rebro = broadcast_winner(onehot_select(new_p, sel), new_p)
                return rebro, vlosses, sel
            return round_body
        return lambda params, inputs, val: self._sharded(
            params, inputs, val, select=True)

    def accept_fn(self) -> Callable:
        """(params, inputs, val) -> (committed_params, fetch): the fused
        round-acceptance cascade.  ``committed_params`` is the accepted
        winner (theta^{t+1}) or the unchanged ``params`` when every
        candidate fails verification; ``fetch`` is the
        ``repro.selection.cascade.pack_fetch`` vector — the drivers' single
        host sync per round.  Protocol layout only (``params`` is the
        single theta broadcast into every cluster)."""
        if self.params_stacked:
            raise ValueError("accept_fn requires the protocol layout "
                             "(params_stacked=False): the commit stage "
                             "resolves the R candidates back to one theta")
        if self.verify.enabled and self.verify.recompute \
                and self.spec.handoff_acts is None:
            raise ValueError("verify.enabled with recompute needs the "
                             "RoundSpec handoff_acts hook")
        if self.placement == "vmap":
            return self._accept_vmap
        return lambda params, inputs, val: self._sharded_accept(
            params, inputs, val)

    def _verify_passed(self, new_p, vaux, val):
        """Per-candidate handoff verification: compare the first clients'
        re-transmission (re-derived from the handed-off parameters when
        ``verify.recompute``, else the validation-time transmission itself —
        see :class:`VerifyConfig`) against the validation-time activations
        with the Pallas tamper-check distance.  Returns a bool pass mask
        over the leading candidate axis."""
        from ..kernels.ops import tamper_distance
        if self.verify.recompute:
            recv = jax.vmap(lambda p: self.spec.handoff_acts(p, val))(new_p)
        else:
            recv = vaux
        dists = jax.vmap(tamper_distance)(vaux, recv)
        return dists <= jnp.float32(self.verify.tol), dists

    def _accept_vmap(self, params, inputs, val):
        from ..selection import masked_first_accept, pack_fetch
        spec, policy = self.spec, self.select
        new_p, aux, vlosses, vaux, shard_l = select_map(
            spec, policy, params, inputs, val, False)
        ctx = policy_context(spec, policy, aux, vlosses, shard_l)
        scores, elig = policy_scores(policy, ctx)
        if self.verify.enabled:
            passed, _ = self._verify_passed(new_p, vaux, val)
        else:
            passed = jnp.ones_like(elig)
        sel, det, acc = masked_first_accept(scores, elig, passed)
        winner = onehot_select(new_p, sel)
        committed = jax.tree.map(lambda w, old: jnp.where(acc, w, old),
                                 winner, params)
        fetch = pack_fetch(vlosses, _spec_train_summary(spec, aux, vlosses),
                           sel, det, acc)
        return committed, fetch

    def sweep_fn(self) -> Callable:
        """(params_S, inputs_SR, val) -> (winner_params_S, train_aux_SR,
        vlosses_SR, sel_S): one global round of S independent replicas with
        the per-seed policy selection inside the compiled program."""
        if self.placement == "vmap":
            return lambda params, inputs, val: sweep_map(
                self.spec, params, inputs, val, self.params_stacked,
                self.select)
        return self._sharded_sweep

    # -- round-block entries: K rounds as one lax.scan, one host fetch -------

    def accept_block_fn(self) -> Callable:
        """(params, block_inputs, val) -> (committed_params, fetches): K
        consecutive fused acceptance rounds chained as a single
        ``jax.lax.scan`` over the round axis.  ``block_inputs`` leaves lead
        with K (each step slice is exactly one :meth:`accept_fn` payload);
        the carry is theta and is donated at the jit boundary, so the scan
        reuses the parameter buffers in place.  ``fetches`` stacks the K
        per-round ``pack_fetch`` vectors to (K, 2R+3) — ONE host sync per
        block, from which the drivers replay per-round History/telemetry/
        CommMeter records bit-identically to per-round execution (the scan
        body IS the per-round accept program)."""
        body = self.accept_fn()

        def block_body(params, block_inputs, val):
            def step(theta, inputs):
                return body(theta, inputs, val)

            return jax.lax.scan(step, params, block_inputs)

        return block_body

    def sweep_block_fn(self) -> Callable:
        """(params_S, block_inputs, val) -> (winner_params_S, (vlosses_KSR,
        tlosses_KSR, sels_KS)): K sweep rounds as one scan.  The per-round
        train-loss reduction (mean over the client axis) moves inside the
        program so the stacked ys stay small — the same ``jnp.mean`` the
        per-round driver applies to the fetched aux, hence bit-identical."""
        body = self.sweep_fn()

        def block_body(params, block_inputs, val):
            def step(theta_s, inputs):
                new_thetas, aux, vlosses, sels = body(theta_s, inputs, val)
                tl = aux[0] if isinstance(aux, tuple) else aux
                return new_thetas, (vlosses, jnp.mean(tl, axis=-1), sels)

            return jax.lax.scan(step, params, block_inputs)

        return block_body

    def round_block_fn(self) -> Callable:
        """(stacked_params, block_batches, val) -> (rebro_params_R,
        (vlosses_KR, sels_K)): K full launch-layer rounds (in-program policy
        selection + winner broadcast) as one scan — the block variant of
        :meth:`round_fn` for the ``make_pigeon_round_step`` family.  The
        stacked-params carry is donated at the jit boundary."""
        body = self.round_fn()

        def block_body(params, block_batches, val):
            def step(stacked, batches):
                rebro, vlosses, sel = body(stacked, batches, val)
                return rebro, (vlosses, sel)

            return jax.lax.scan(step, params, block_batches)

        return block_body

    # -- job-pool entry: J jobs x K rounds, one program, one fetch -----------

    def pool_accept_block_fn(self) -> Callable:
        """(params_J, block_inputs_J, val_J, active_J) -> (committed_J,
        fetches_J): J independent jobs' round blocks batched onto a leading
        job lane of the :meth:`accept_block_fn` program.  Every leaf of
        ``params_J`` / ``block_inputs_J`` / ``val_J`` leads with J;
        ``active_J`` is a (J,) bool lane mask — a masked (idle) lane runs
        the same arithmetic on its placeholder payload but its commit is
        discarded (``jnp.where(active, new, old)``), so ragged pools cost no
        recompile.  ``fetches_J`` stacks to (J, K, 2R+3) — ONE host sync per
        pool block, from which the pool driver replays every lane's
        per-round records exactly as the solo driver would.  The per-lane
        body is literally the scan of :meth:`accept_fn`'s vmap cascade, so
        an active lane is bit-identical to running its job alone.

        Under ``placement="sharded"`` the JOB axis (not the cluster axis)
        lays over the mesh: jobs are embarrassingly parallel with no
        cross-lane collectives, so each shard just vmaps its local lane
        slice.  Protocol layout only, like :meth:`accept_fn`."""
        if self.params_stacked:
            raise ValueError("pool_accept_block_fn requires the protocol "
                             "layout (params_stacked=False)")
        if self.verify.enabled and self.verify.recompute \
                and self.spec.handoff_acts is None:
            raise ValueError("verify.enabled with recompute needs the "
                             "RoundSpec handoff_acts hook")
        body = self._accept_vmap

        def one_job(params, block_inputs, val, active):
            def step(theta, inputs):
                return body(theta, inputs, val)

            new_p, fetches = jax.lax.scan(step, params, block_inputs)
            committed = jax.tree.map(
                lambda n, o: jnp.where(active, n, o), new_p, params)
            return committed, fetches

        def pool_lanes(params, block_inputs, val, active):
            # bit-identity corner: a size-1 vmap vectorises the batch-mean
            # reductions differently from the unvmapped scan (last-float-bit
            # drift vs solo), so a single (local) lane runs ``one_job`` on
            # the squeezed tree — literally the solo block program — and the
            # lane axis is reshaped back on
            if active.shape[0] == 1:
                sq = lambda t: jax.tree.map(lambda a: a[0], t)
                c, f = one_job(sq(params), sq(block_inputs), sq(val),
                               active[0])
                return (jax.tree.map(lambda a: a[None], c),
                        jax.tree.map(lambda a: a[None], f))
            return jax.vmap(one_job)(params, block_inputs, val, active)

        if self.placement == "vmap":
            return pool_lanes

        def pool_sharded(params_j, block_inputs_j, val_j, active_j):
            ax = self.cluster_axis
            j = active_j.shape[0]
            mesh = self.mesh if self.mesh is not None else cluster_mesh(j)
            if j % mesh.shape[ax]:
                raise ValueError(f"J={j} not divisible by mesh axis "
                                 f"{ax!r}={mesh.shape[ax]}")
            fn = _apply_shard_map(
                pool_lanes,
                mesh, (P(ax), P(ax), P(ax), P(ax)), (P(ax), P(ax)), ax)
            return fn(params_j, block_inputs_j, val_j, active_j)

        return pool_sharded

    # -- sharded placement --------------------------------------------------

    def _gathered_context(self, aux, vloss, shard_l, ax):
        """All-gather the local selection features across the cluster mesh
        axis and build the global ScoreContext every shard scores
        identically (policy stages are pure jnp — no collectives inside)."""
        from ..selection import ScoreContext
        spec, policy = self.spec, self.select
        losses_g = jax.lax.all_gather(vloss, ax, tiled=True)          # (R,)
        shard_g = (None if shard_l is None
                   else jax.lax.all_gather(shard_l, ax, tiled=True))
        stats_g = None
        if policy.needs_message_stats:
            if spec.message_stats is None:
                raise ValueError(f"selection policy {policy.name!r} needs "
                                 f"transmitted-message statistics, which "
                                 f"this RoundSpec does not surface")
            stats_g = jax.lax.all_gather(spec.message_stats(aux), ax,
                                         tiled=True)
        return ScoreContext(vlosses=losses_g, shard_losses=shard_g,
                            message_stats=stats_g)

    def _psum_pick(self, new_p, sel, ax):
        """One-hot psum contraction of the global winner out of the local
        candidate slices (a single masked all-reduce per leaf)."""
        r_local = jax.tree.leaves(new_p)[0].shape[0]
        mine = (jax.lax.axis_index(ax) * r_local + jnp.arange(r_local)) == sel

        def pick(x):
            mask = mine.reshape((-1,) + (1,) * (x.ndim - 1))
            local = jnp.sum(jnp.where(mask, x.astype(jnp.float32),
                                      jnp.float32(0.0)),
                            axis=0)
            return jax.lax.psum(local, ax).astype(x.dtype)

        return jax.tree.map(pick, new_p)

    def _sharded(self, params, inputs, val, select: bool):
        ax = self.cluster_axis
        r = jax.tree.leaves(inputs)[0].shape[0]
        mesh = self.mesh if self.mesh is not None else cluster_mesh(r)
        if r % mesh.shape[ax]:
            raise ValueError(f"R={r} not divisible by mesh axis "
                             f"{ax!r}={mesh.shape[ax]}")

        def per_shard(params_s, inputs_s, val_s):
            # params_s: the local R_local slice (stacked) or the full
            # replicated pytree; inputs_s: the local cluster slice.
            if not select:
                return cluster_map(self.spec, params_s, inputs_s, val_s,
                                   self.params_stacked)
            new_p, aux, vloss, _, shard_l = select_map(
                self.spec, self.select, params_s, inputs_s, val_s,
                self.params_stacked)
            ctx = self._gathered_context(aux, vloss, shard_l, ax)
            scores, elig = policy_scores(self.select, ctx)
            sel = masked_argmin(scores, elig)
            rebro = broadcast_winner(self._psum_pick(new_p, sel, ax), new_p)
            return rebro, ctx.vlosses, sel

        p_spec = P(ax) if self.params_stacked else P()
        in_specs = (p_spec, P(ax), P())
        out_specs = ((P(ax), P(), P()) if select
                     else (P(ax), P(ax), P(ax), P(ax)))
        fn = _apply_shard_map(per_shard, mesh, in_specs, out_specs, ax)
        return fn(params, inputs, val)

    def _sharded_accept(self, params, inputs, val):
        from ..selection import masked_first_accept, pack_fetch
        ax = self.cluster_axis
        r = jax.tree.leaves(inputs)[0].shape[0]
        mesh = self.mesh if self.mesh is not None else cluster_mesh(r)
        if r % mesh.shape[ax]:
            raise ValueError(f"R={r} not divisible by mesh axis "
                             f"{ax!r}={mesh.shape[ax]}")
        spec, policy = self.spec, self.select

        def per_shard(params_s, inputs_s, val_s):
            new_p, aux, vloss, vaux, shard_l = select_map(
                spec, policy, params_s, inputs_s, val_s, False)
            ctx = self._gathered_context(aux, vloss, shard_l, ax)
            scores, elig = policy_scores(policy, ctx)
            if self.verify.enabled:
                passed_l, _ = self._verify_passed(new_p, vaux, val_s)
                passed = jax.lax.all_gather(passed_l, ax, tiled=True)
            else:
                passed = jnp.ones_like(elig)
            sel, det, acc = masked_first_accept(scores, elig, passed)
            winner = self._psum_pick(new_p, sel, ax)
            committed = jax.tree.map(lambda w, old: jnp.where(acc, w, old),
                                     winner, params_s)
            summary = jax.lax.all_gather(
                _spec_train_summary(spec, aux, vloss), ax, tiled=True)
            fetch = pack_fetch(ctx.vlosses, summary, sel, det, acc)
            return committed, fetch

        in_specs = (P(), P(ax), P())
        out_specs = (P(), P())
        fn = _apply_shard_map(per_shard, mesh, in_specs, out_specs, ax)
        return fn(params, inputs, val)

    def _sharded_sweep(self, params, inputs, val):
        ax, sax = self.cluster_axis, self.seed_axis
        leaf = jax.tree.leaves(inputs)[0]
        s, r = leaf.shape[0], leaf.shape[1]
        mesh = self.mesh if self.mesh is not None else sweep_mesh(s, r)
        if s % mesh.shape[sax] or r % mesh.shape[ax]:
            raise ValueError(f"(S={s}, R={r}) not divisible by mesh axes "
                             f"({sax!r}={mesh.shape[sax]}, "
                             f"{ax!r}={mesh.shape[ax]})")

        def per_shard(params_s, inputs_s, val_s):
            # params_s: (S_local, ...) [+ cluster dim when stacked];
            # inputs_s: the local (S_local, R_local, ...) replica block.
            new_p, aux, vloss, _, shard_l = jax.vmap(
                lambda p, i: select_map(self.spec, self.select, p, i, val_s,
                                        self.params_stacked)
            )(params_s, inputs_s)
            losses = jax.lax.all_gather(vloss, ax, axis=1, tiled=True)  # (S_local, R)
            shard_g = (None if shard_l is None
                       else jax.lax.all_gather(shard_l, ax, axis=1, tiled=True))
            stats_g = None
            if self.select.needs_message_stats:
                stats_g = jax.lax.all_gather(
                    jax.vmap(self.spec.message_stats)(aux), ax, axis=1,
                    tiled=True)

            def choose(vl, sl, st):
                from ..selection import ScoreContext
                ctx = ScoreContext(vlosses=vl, shard_losses=sl,
                                   message_stats=st)
                scores, elig = policy_scores(self.select, ctx)
                return masked_argmin(scores, elig)

            sels = jax.vmap(choose, in_axes=(
                0, None if shard_g is None else 0,
                None if stats_g is None else 0))(losses, shard_g, stats_g)
            r_local = vloss.shape[1]
            mine = (jax.lax.axis_index(ax) * r_local
                    + jnp.arange(r_local))[None, :] == sels[:, None]

            def pick(x):
                mask = mine.reshape(mine.shape + (1,) * (x.ndim - 2))
                local = jnp.sum(jnp.where(mask, x.astype(jnp.float32),
                                      jnp.float32(0.0)),
                                axis=1)
                return jax.lax.psum(local, ax).astype(x.dtype)

            return jax.tree.map(pick, new_p), aux, losses, sels

        p_spec = P(sax, ax) if self.params_stacked else P(sax)
        in_specs = (p_spec, P(sax, ax), P())
        out_specs = (P(sax), P(sax, ax), P(sax), P(sax))
        fn = _apply_shard_map(per_shard, mesh, in_specs, out_specs, (sax, ax))
        return fn(params, inputs, val)

    # -- jitted convenience entry points ------------------------------------

    def _check_executable(self, manual_axes) -> None:
        if self.placement == "sharded" and self.mesh is not None:
            check_partial_auto_backend(self.mesh, manual_axes)

    # Entries whose params/theta carry is donated at the jit boundary: the
    # drivers rebind theta every call (theta = accept(theta, ...)), so XLA
    # may reuse the carry buffers in place instead of allocating a second
    # parameter set per round.  "candidates" is NOT donated — the host-side
    # reference cascade (select_host) may roll back to the original theta —
    # and neither is "round", whose launch/test callers legitimately reuse
    # the same stacked params across runners.
    _DONATED = frozenset({"accept", "sweep", "accept_block", "sweep_block",
                          "round_block", "pool_accept_block"})

    ENTRIES = ("candidates", "round", "accept", "sweep", "accept_block",
               "sweep_block", "round_block", "pool_accept_block")

    def audit_body(self, which: str) -> Callable:
        """The un-jitted body of one entry — the static-analysis layer
        retraces this under alternative configs (e.g. ``enable_x64`` to
        surface weak-type f64 promotion) without touching the dispatch
        cache."""
        return {"candidates": self.candidates_fn, "round": self.round_fn,
                "accept": self.accept_fn, "sweep": self.sweep_fn,
                "accept_block": self.accept_block_fn,
                "sweep_block": self.sweep_block_fn,
                "round_block": self.round_block_fn,
                "pool_accept_block": self.pool_accept_block_fn}[which]()

    def donated_argnums(self, which: str) -> tuple:
        return (0,) if which in self._DONATED else ()

    def _compiled(self, which: str) -> Callable:
        fn = self._jitted.get(which)
        if fn is None:
            fn = jax.jit(self.audit_body(which),
                         donate_argnums=self.donated_argnums(which))
            self._jitted[which] = fn
        return fn

    def lower(self, which: str, *args):
        """Audit hook: the lowered (pre-compile) program of a jitted entry,
        donation flags included.  Shares ``_jitted`` with dispatch, so the
        auditor provably sees the same program object the drivers run."""
        return self._compiled(which).lower(*args)

    def _call(self, which: str, *args):
        """Invoke a jitted entry, recording the first call's wall time
        (trace + XLA compile + first dispatch) for telemetry.  Only the
        monotonic clock is read — no effect on the computation."""
        fn = self._compiled(which)
        if which in self._trace_compile_s:
            return fn(*args)
        t0 = time.perf_counter()
        out = fn(*args)
        self._trace_compile_s[which] = time.perf_counter() - t0
        return out

    def candidates(self, params, inputs, val):
        self._check_executable((self.cluster_axis,))
        return self._call("candidates", params, inputs, val)

    def round(self, params, inputs, val):
        self._check_executable((self.cluster_axis,))
        return self._call("round", params, inputs, val)

    def accept(self, params, inputs, val):
        """Fused round acceptance: (committed_params, fetch) — see
        :meth:`accept_fn`."""
        self._check_executable((self.cluster_axis,))
        return self._call("accept", params, inputs, val)

    def sweep(self, params, inputs, val):
        self._check_executable((self.seed_axis, self.cluster_axis))
        return self._call("sweep", params, inputs, val)

    def accept_block(self, params, block_inputs, val):
        """K scanned acceptance rounds, one stacked (K, 2R+3) fetch — see
        :meth:`accept_block_fn`.  The theta carry is donated."""
        self._check_executable((self.cluster_axis,))
        return self._call("accept_block", params, block_inputs, val)

    def sweep_block(self, params, block_inputs, val):
        self._check_executable((self.seed_axis, self.cluster_axis))
        return self._call("sweep_block", params, block_inputs, val)

    def pool_accept_block(self, params_j, block_inputs_j, val_j, active_j):
        """J jobs x K scanned acceptance rounds, one stacked (J, K, 2R+3)
        fetch — see :meth:`pool_accept_block_fn`.  The theta_J carry is
        donated."""
        self._check_executable((self.cluster_axis,))
        return self._call("pool_accept_block", params_j, block_inputs_j,
                          val_j, active_j)

    def round_block(self, params, block_batches, val):
        self._check_executable((self.cluster_axis,))
        return self._call("round_block", params, block_batches, val)


# ---------------------------------------------------------------------------
# the protocol-level binding (SplitModule + AttackVec lanes)
# ---------------------------------------------------------------------------

def sharded_validation_losses(module, phi, acts, y0, k: int) -> jnp.ndarray:
    """(k',) per-shard shared-set losses from the validation activations —
    THE one copy of the median-of-means shard arithmetic, shared by the
    pigeon and SplitFed spec bindings and the host selector
    (``repro.selection.selector._shard_loss_fn``)."""
    from ..selection import effective_shards
    kk = effective_shards(k, acts.shape[0])
    shard_acts = acts.reshape((kk, acts.shape[0] // kk) + acts.shape[1:])
    shard_y = y0.reshape((kk, y0.shape[0] // kk) + y0.shape[1:])
    return jax.vmap(lambda a, y: module.ap_loss(phi, a, y))(shard_acts,
                                                            shard_y)


def make_train_summary(with_stats: bool):
    """The SplitModule specs' ``train_summary`` hook: per-cluster mean
    client loss out of the (losses[, stats]) aux convention."""

    def train_summary(aux):
        losses = aux[0] if with_stats else aux
        return jnp.mean(losses, axis=-1)

    return train_summary

@lru_cache(maxsize=None)
def protocol_round_spec(module, lr: float, with_stats: bool = False,
                        quant: Optional[str] = None) -> RoundSpec:
    """Pigeon per-cluster programs over a ``SplitModule``: the within-cluster
    client-chain scan with the AttackVec threat-model lanes from the
    adversary subsystem (``inputs = (xs, ys, avec, keys)``, every leaf with
    leading axis M_bar), and shared-set validation returning the cut
    activations the tamper check compares against (``val = (x0, y0)``).

    The selection hooks bind the full policy feature set: sharded shared-set
    validation (median-of-means), the handoff re-transmission (the fused
    verify stage), and — under ``with_stats`` — the per-client
    transmitted-message statistics (``core.split.message_stats``) that the
    anomaly-scoring policies read.  ``with_stats=False`` compiles exactly
    the pre-selection-subsystem round program."""
    from .split import client_update_vec_impl, client_update_vec_stats_impl

    def train_cluster(theta, inputs):
        xs_c, ys_c, av_c, keys_c = inputs
        gamma, phi = theta

        def per_client(carry, inp):
            g, p = carry
            x, y, av, k = inp
            if with_stats:
                g, p, loss, stats = client_update_vec_stats_impl(
                    module, av, g, p, (x, y), lr, k, quant=quant)
                return (g, p), (loss, stats)
            g, p, loss = client_update_vec_impl(module, av, g, p, (x, y), lr,
                                                k, quant=quant)
            return (g, p), loss

        (g, p), aux = jax.lax.scan(per_client, (gamma, phi),
                                   (xs_c, ys_c, av_c, keys_c))
        return (g, p), aux

    def validate(theta, val):
        g, p = theta
        x0, y0 = val
        acts = module.client_forward(g, x0)
        return module.ap_loss(p, acts, y0), acts

    def validate_sharded(theta, val, k):
        g, p = theta
        x0, y0 = val
        acts = module.client_forward(g, x0)
        shard_losses = sharded_validation_losses(module, p, acts, y0, k)
        # History's vloss stays the exact full-set loss (same op as
        # ``validate``, the forward is shared); the shards only feed scores
        return module.ap_loss(p, acts, y0), shard_losses, acts

    def handoff_acts(theta, val):
        return module.client_forward(theta[0], val[0])

    return RoundSpec(
        train_cluster, validate,
        validate_sharded=validate_sharded,
        handoff_acts=handoff_acts,
        train_summary=make_train_summary(with_stats),
        message_stats=(lambda aux: aux[1]) if with_stats else None)


@lru_cache(maxsize=None)
def protocol_runner(module, lr: float, placement: str = "vmap",
                    with_stats: bool = False, select=None,
                    quant: Optional[str] = None) -> RoundRunner:
    """Cached per (module, lr, placement, stats, policy, quant) so every
    round reuses one compiled program — the protocol layout (theta broadcast
    into all clusters)."""
    return RoundRunner(protocol_round_spec(module, lr, with_stats, quant),
                       placement=placement, select=select)


@lru_cache(maxsize=None)
def protocol_accept_runner(module, lr: float, placement: str, select,
                           tamper_check: bool, tamper_tol: float,
                           quant: Optional[str] = None) -> RoundRunner:
    """The fused-acceptance runner the protocol drivers use on the default
    batched path: the policy's score/eligibility stages + the masked
    rank/verify/commit cascade compiled into one round program."""
    spec = protocol_round_spec(module, lr,
                               with_stats=select.needs_message_stats,
                               quant=quant)
    # recompute=False: this runner only ever runs under the no-param-tamper
    # precondition (engine.pigeon_round_accept asserts it), where the
    # re-transmission equals the validation activations by construction.
    return RoundRunner(spec, placement=placement, select=select,
                       verify=VerifyConfig(enabled=tamper_check,
                                           tol=tamper_tol,
                                           recompute=False))
