"""Pigeon-SL: the paper's primary contribution.

Clustered split learning with pigeonhole-guaranteed honest clusters,
shared-dataset validation selection, tamper-resilient parameter handoff and
the throughput-matched Pigeon-SL+ variant.  Adversaries — attack families,
round-indexed schedules and heterogeneous per-client threat models — come
from the pluggable ``repro.adversary`` subsystem.
"""
from ..adversary import (ALWAYS, BACKDOOR, GRAD_NOISE, GRAD_SCALE, REPLAY,
                         STEALTH, ClientThreat, Schedule, ThreatModel,
                         after_warmup, every_k, ramp, stealth)
from ..selection import (LossPlusDistancePolicy, MedianOfMeansPolicy,
                         SelectionPolicy, TrimmedPolicy, resolve_policy,
                         selection_policies)
from ..telemetry import Telemetry

from .attacks import (ACTIVATION, GRADIENT, HONEST, KINDS, LABEL_FLIP, NONE,
                      PARAM_TAMPER, Attack, AttackVec, attack_vec,
                      attack_vec_for_clusters)
from .clustering import cluster_is_honest, has_honest_cluster, make_clusters
from .comm import (QUANT_FORMATS, CommConfig, fp8_supported, message_bytes,
                   resolve_quant)
from .compile_cache import compile_cache_stats, enable_compile_cache
from .engine import (batched_round, onehot_select, run_pigeon_sweep,
                     train_round_batched)
from .jobs import JobPool, JobSpec, run_job_pool
from .protocol import (ENGINES, ClientData, CommMeter, History, ProtocolConfig,
                       run_pigeon, run_pigeon_plus, run_splitfed,
                       run_vanilla_sl)
from .runner import (PLACEMENTS, RoundRunner, RoundSpec, VerifyConfig,
                     check_partial_auto_backend, cluster_map, cluster_mesh,
                     protocol_accept_runner, protocol_round_spec,
                     protocol_runner, select_map, sweep_map, sweep_mesh)
from .split import (SplitModule, client_update, client_update_vec, from_cnn,
                    from_lm, sl_minibatch_grads, sl_minibatch_grads_vec)
from .validation import check_handoff, select_cluster, validation_loss

__all__ = [
    "Attack", "HONEST", "NONE", "LABEL_FLIP", "ACTIVATION", "GRADIENT",
    "PARAM_TAMPER", "BACKDOOR", "GRAD_SCALE", "GRAD_NOISE", "REPLAY",
    "STEALTH", "stealth", "KINDS",
    "AttackVec", "attack_vec", "attack_vec_for_clusters",
    "ThreatModel", "ClientThreat", "Schedule", "ALWAYS", "every_k",
    "after_warmup", "ramp",
    "make_clusters", "has_honest_cluster", "cluster_is_honest",
    "ClientData", "CommMeter", "CommConfig", "QUANT_FORMATS", "fp8_supported",
    "message_bytes", "resolve_quant", "History", "ProtocolConfig", "ENGINES",
    "enable_compile_cache", "compile_cache_stats",
    "Telemetry",
    "run_pigeon", "run_pigeon_plus", "run_splitfed", "run_vanilla_sl",
    "run_pigeon_sweep", "batched_round", "train_round_batched", "onehot_select",
    "JobSpec", "JobPool", "run_job_pool",
    "PLACEMENTS", "RoundRunner", "RoundSpec", "VerifyConfig", "cluster_map",
    "select_map", "cluster_mesh", "sweep_map", "sweep_mesh",
    "check_partial_auto_backend", "protocol_round_spec", "protocol_runner",
    "protocol_accept_runner",
    "SelectionPolicy", "MedianOfMeansPolicy", "LossPlusDistancePolicy",
    "TrimmedPolicy", "resolve_policy", "selection_policies",
    "SplitModule", "client_update", "client_update_vec", "from_cnn", "from_lm",
    "sl_minibatch_grads", "sl_minibatch_grads_vec",
    "check_handoff", "select_cluster", "validation_loss",
]
