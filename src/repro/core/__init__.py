"""Pigeon-SL: the paper's primary contribution.

Clustered split learning with pigeonhole-guaranteed honest clusters,
shared-dataset validation selection, tamper-resilient parameter handoff and
the throughput-matched Pigeon-SL+ variant.
"""
from .attacks import (ACTIVATION, GRADIENT, HONEST, KINDS, LABEL_FLIP, NONE,
                      PARAM_TAMPER, Attack)
from .clustering import cluster_is_honest, has_honest_cluster, make_clusters
from .protocol import (ClientData, CommMeter, History, ProtocolConfig,
                       run_pigeon, run_splitfed, run_vanilla_sl)
from .split import SplitModule, client_update, from_cnn, from_lm, sl_minibatch_grads
from .validation import check_handoff, select_cluster, validation_loss

__all__ = [
    "Attack", "HONEST", "NONE", "LABEL_FLIP", "ACTIVATION", "GRADIENT",
    "PARAM_TAMPER", "KINDS",
    "make_clusters", "has_honest_cluster", "cluster_is_honest",
    "ClientData", "CommMeter", "History", "ProtocolConfig",
    "run_pigeon", "run_splitfed", "run_vanilla_sl",
    "SplitModule", "client_update", "from_cnn", "from_lm", "sl_minibatch_grads",
    "check_handoff", "select_cluster", "validation_loss",
]
