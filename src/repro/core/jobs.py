"""Job-pool execution layer: megabatching concurrent Pigeon-SL jobs.

The production regime the ROADMAP targets is many concurrent *small* jobs —
per-tenant protocol instances — not one big one, and a solo ``run_pigeon``
pays its own dispatch, compile and host-sync cost per round.  The sweep path
proves S x R protocol replicas share one device program and round-block
fusion proves K rounds share one dispatch; this module combines them at the
job level:

* :class:`JobSpec` — one tenant's run: module, data, protocol config, threat
  model, selection policy, quant format, checkpoint/resume knobs.
* :class:`JobPool` — shape-buckets compatible specs (same module / lr /
  M / R / E / B / tamper config / policy / quant / data shapes — everything
  that shapes or parameterises the compiled round program).  Seeds, horizons
  T, threat models and eval/checkpoint cadences stay free per job: threat
  state is data (``AttackVec`` lanes), not program.
* :func:`run_job_pool` — executes each bucket round-block by round-block on
  the :meth:`RoundRunner.pool_accept_block` entry: J jobs stacked onto a
  leading job lane of the ``accept_block`` scan, masked lanes for ragged
  pools, ONE compiled program per bucket and ONE stacked ``(J, K, 2R+3)``
  host fetch per block.  Lanes recycle elastically — a job that finishes its
  T rounds frees its lane, refilled from the bucket queue between blocks —
  and results fan out to per-job :class:`History`, crash-atomic per-job
  checkpoints and job-tagged telemetry round events.

Bit-identity contract: the pooled body is literally the scan of the solo
fused cascade, per-lane host assembly consumes each job's numpy RNG and JAX
key streams in exactly the solo order, and the CommMeter replay reuses the
solo accounting helpers — so every job's ``History`` is bit-identical to
running it alone (``tests/test_jobs.py`` pins this across placements, block
sizes and mid-pool refill).

Preconditions (validated up front, raising instead of degrading — a pool
cannot fall back to host-side selection for one lane): no param-tamper
threat models, no Pigeon-SL+ sub-rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..adversary import ThreatModel, resolve_threat_model
from ..selection import resolve_policy, unpack_block_fetch
from ..telemetry import pool_gauges, resolve_telemetry
from .attacks import Attack, HONEST
from .clustering import cluster_is_honest
from .comm import CommConfig
from .protocol import (ClientData, CommMeter, History, ProtocolConfig,
                       _count_params, account_client_turn,
                       account_handoff_recheck, account_param_transfer,
                       account_validation, check_block, cut_width, evaluate)
from .runner import check_placement, protocol_accept_runner
from .split import SplitModule

Pytree = Any


@dataclasses.dataclass(frozen=True, eq=False)
class JobSpec:
    """One tenant's Pigeon-SL run, as the pool scheduler sees it.

    ``name`` keys the job's History / checkpoints / telemetry tags and must
    be unique within a pool.  ``threat_model`` / ``(malicious, attack)``
    follow the ``run_pigeon`` resolution rules; ``selection`` is a policy
    name or instance; ``quant`` overrides ``pcfg.comm`` exactly as the solo
    driver's kwarg does."""
    name: str
    module: SplitModule
    data: ClientData
    pcfg: ProtocolConfig
    malicious: Optional[Set[int]] = None
    attack: Attack = HONEST
    threat_model: Optional[ThreatModel] = None
    selection: Any = "argmin"
    quant: Optional[str] = None
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 1
    resume: bool = False


def _resolved_pcfg(spec: JobSpec) -> ProtocolConfig:
    if spec.quant is None:
        return spec.pcfg
    return dataclasses.replace(spec.pcfg, comm=CommConfig(quant=spec.quant))


def validate_job(spec: JobSpec, block: int = 1) -> Tuple[Any, ThreatModel,
                                                         ProtocolConfig]:
    """Resolve and validate one spec for pool execution: returns
    ``(policy, threat_model, resolved_pcfg)``.  Conditions the solo driver
    degrades per run (param-tamper pinning selection to the host cascade)
    RAISE here — a pooled lane cannot switch execution model without
    breaking the shared program — while the solo :func:`check_block`
    cadence warnings still apply per job."""
    policy = resolve_policy(spec.selection)
    tm = resolve_threat_model(spec.malicious, spec.attack, spec.threat_model)
    pcfg = _resolved_pcfg(spec)
    if tm.has_param_tamper:
        raise ValueError(
            f"job {spec.name!r}: param-tamper threat models need host-side "
            f"selection (per-candidate key splits) and cannot run in a job "
            f"pool — run it solo via run_pigeon")
    if pcfg.M % pcfg.R:
        raise ValueError(f"job {spec.name!r}: M={pcfg.M} not divisible by "
                         f"R={pcfg.R}")
    check_block(block, "batched", plus=False, has_param_tamper=False,
                force_host_selection=False, eval_every=pcfg.eval_every,
                checkpoint_path=spec.checkpoint_path,
                checkpoint_every=spec.checkpoint_every)
    return policy, tm, pcfg


def bucket_key(spec: JobSpec) -> tuple:
    """The shape-bucket key: everything that parameterises or shapes the
    compiled pool program.  Jobs agreeing on this key share ONE compiled
    program (the same lru-cached :func:`protocol_accept_runner` the solo
    driver uses); seed, T, threat model and sync cadences are data or host
    schedule, never program."""
    pcfg = _resolved_pcfg(spec)
    d = spec.data
    return (spec.module, pcfg.lr, pcfg.M, pcfg.R, pcfg.E, pcfg.B,
            pcfg.tamper_check, pcfg.tamper_tol, resolve_policy(spec.selection),
            pcfg.comm.quant,
            d.x.shape, d.x.dtype.str, d.y.shape, d.y.dtype.str,
            d.x0.shape, d.x0.dtype.str, d.y0.shape, d.y0.dtype.str)


class JobPool:
    """Validated, bucketed job queue.  ``buckets()`` yields the spec groups
    in first-seen order; specs inside a bucket keep submission order (the
    lane-refill order)."""

    def __init__(self, specs: Sequence[JobSpec], *, block: int = 1,
                 placement: str = "vmap"):
        check_placement(placement)
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate job names in pool: {dupes}")
        if not specs:
            raise ValueError("empty job pool")
        self.specs = list(specs)
        self.block = block
        self.placement = placement
        self._resolved = [validate_job(s, block) for s in specs]
        self._buckets: Dict[tuple, List[int]] = {}
        for i, s in enumerate(specs):
            self._buckets.setdefault(bucket_key(s), []).append(i)

    def buckets(self) -> List[List[int]]:
        """Job indices per shape bucket, first-seen bucket order."""
        return list(self._buckets.values())

    def resolved(self, i: int) -> Tuple[Any, ThreatModel, ProtocolConfig]:
        return self._resolved[i]


# ---------------------------------------------------------------------------
# per-job protocol state (solo-init discipline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _JobState:
    spec: JobSpec
    policy: Any
    tm: ThreatModel
    pcfg: ProtocolConfig
    rng: np.random.Generator
    key: jax.Array
    theta: Pytree
    t: int                          # next round to run
    hist: History
    d_cl: int
    d_c: int
    d_o: int
    x0: jnp.ndarray
    y0: jnp.ndarray
    terminal: bool = False          # resumed past T-1: nothing to train

    def ckpt_due(self, t: int) -> bool:
        return self.spec.checkpoint_path is not None and (
            (t + 1) % self.spec.checkpoint_every == 0
            or t == self.pcfg.T - 1)

    def is_sync(self, t: int) -> bool:
        return (t % self.pcfg.eval_every == 0 or t == self.pcfg.T - 1
                or self.ckpt_due(t))


def _init_job(spec: JobSpec, policy, tm: ThreatModel,
              pcfg: ProtocolConfig) -> _JobState:
    """Mirror of ``run_pigeon``'s init + resume preamble, per job: the same
    RNG/key/init draws in the same order, the same on-stream checkpoint
    restore, the same terminal-resume short-circuit."""
    rng = np.random.default_rng(pcfg.seed)
    key = jax.random.PRNGKey(pcfg.seed)
    key, k0 = jax.random.split(key)
    theta = spec.module.init(k0)
    start_round = 0
    if spec.resume and spec.checkpoint_path is not None:
        from ..checkpoint import (CorruptCheckpointError, load_checkpoint,
                                  restore_protocol_state, restore_pytree)
        from .clustering import make_clusters
        try:
            _, meta = load_checkpoint(spec.checkpoint_path)
            theta = restore_pytree(spec.checkpoint_path, theta)
            start_round = int(meta.get("round", -1)) + 1
            if "rng_state" in meta:
                key = restore_protocol_state(rng, key, meta)
            else:
                for _ in range(start_round):
                    make_clusters(rng, pcfg.M, pcfg.R)
        except FileNotFoundError:
            start_round = 0
        except CorruptCheckpointError as e:
            import warnings
            warnings.warn(f"job {spec.name!r}: ignoring corrupt checkpoint "
                          f"{spec.checkpoint_path!r} ({e}); starting from "
                          f"round 0", stacklevel=2)
            start_round = 0
    st = _JobState(
        spec=spec, policy=policy, tm=tm, pcfg=pcfg, rng=rng, key=key,
        theta=theta, t=start_round, hist=History(),
        d_cl=_count_params(theta[0]),
        d_c=cut_width(spec.module, theta[0], spec.data.x0),
        d_o=spec.data.x0.shape[0],
        x0=jnp.asarray(spec.data.x0), y0=jnp.asarray(spec.data.y0))
    if start_round >= pcfg.T:
        import warnings
        warnings.warn(
            f"job {spec.name!r}: checkpoint {spec.checkpoint_path!r} is at "
            f"round {start_round - 1} >= T-1 = {pcfg.T - 1}; nothing left "
            f"to train — returning the restored final state", stacklevel=2)
        st.terminal = True
        st.hist.rounds.append(dict(
            round=start_round - 1, resumed_terminal=True,
            test_acc=evaluate(spec.module, theta[0], theta[1],
                              spec.data.x_test, spec.data.y_test,
                              pcfg.eval_batch)))
    return st


# ---------------------------------------------------------------------------
# pool schedule: deterministic up front, so the feeder can run ahead
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _BlockPlan:
    """One pool block: per-lane job index (or -1 for an idle lane), each
    active lane's starting round, and the scanned block length K = min over
    active lanes of the solo segment length (so a lane's sync rounds always
    land on the last round it executes — see ``lane_block_len``)."""
    assign: Tuple[int, ...]
    t0s: Tuple[int, ...]
    k: int


def plan_pool(states: Sequence[_JobState], order: Sequence[int], lanes: int,
              block: int) -> List[_BlockPlan]:
    """The whole pool's block schedule, computed before any round runs.
    Lane occupancy and block lengths depend only on per-job horizons and
    sync cadences — never on training outcomes — so the schedule is
    deterministic and the round feeder can assemble pool payloads ahead of
    device execution without changing any job's RNG/key consumption order."""
    from ..data.pipeline import lane_block_len
    queue = [i for i in order if not states[i].terminal]
    lane_job = [-1] * lanes
    lane_t = [0] * lanes
    for lane in range(lanes):
        if queue:
            j = queue.pop(0)
            lane_job[lane] = j
            lane_t[lane] = states[j].t
    plans: List[_BlockPlan] = []
    while any(j >= 0 for j in lane_job):
        ks = [lane_block_len(lane_t[l], states[j].pcfg.T, block,
                             states[j].is_sync)
              for l, j in enumerate(lane_job) if j >= 0]
        k = min(ks)
        plans.append(_BlockPlan(tuple(lane_job), tuple(lane_t), k))
        for lane, j in enumerate(lane_job):
            if j < 0:
                continue
            lane_t[lane] += k
            if lane_t[lane] >= states[j].pcfg.T:
                if queue:
                    nxt = queue.pop(0)
                    lane_job[lane] = nxt
                    lane_t[lane] = states[nxt].t
                else:
                    lane_job[lane] = -1
    return plans


# ---------------------------------------------------------------------------
# the pool driver
# ---------------------------------------------------------------------------

@jax.jit
def _stack_lanes(leaves):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *leaves)


@jax.jit
def _stack_small_lanes(smalls):
    """Stack J lanes x K rounds of small payloads (AttackVec state, derived
    per-client keys) to a leading (J, K) in ONE dispatch — the per-lane
    eager path costs a stack dispatch per lane, which at small per-round
    compute eats the pool's amortisation win."""
    per_lane = tuple(jax.tree.map(lambda *ls: jnp.stack(ls), *s)
                     for s in smalls)
    return jax.tree.map(lambda *ls: jnp.stack(ls), *per_lane)


def _set_lane(tree_j: Pytree, lane: int, tree: Pytree) -> Pytree:
    return jax.tree.map(lambda full, leaf: full.at[lane].set(leaf),
                        tree_j, tree)


def _lane_slice(tree_j: Pytree, lane: int) -> Pytree:
    return jax.tree.map(lambda a: a[lane], tree_j)


def _replay_lane_rounds(st: _JobState, clusters_k, records, t0: int,
                        theta_lane_of, stream_snap, tel) -> None:
    """Fan one lane's slice of the pool fetch out to per-round History /
    CommMeter / telemetry / checkpoint records — the solo driver's block>1
    replay loop verbatim, so the records are bit-identical to running the
    job alone.  ``theta_lane_of()`` lazily slices the lane's theta out of
    the stacked carry (only eval/checkpoint rounds need it)."""
    pcfg, tm, spec = st.pcfg, st.tm, st.spec
    for i, brec in enumerate(records):
        t = t0 + i
        clusters = clusters_k[i]
        meter = CommMeter()
        for cluster in clusters:
            for j in range(len(cluster)):
                account_client_turn(meter, pcfg, st.d_c, st.d_cl,
                                    handoff=j < len(cluster) - 1)
        if pcfg.tamper_check:
            visited = brec["detections"] + (1 if brec["accepted"] else 0)
            account_handoff_recheck(meter, pcfg, st.d_o, st.d_c, visited)
        for _ in clusters:
            account_validation(meter, st.d_o, st.d_c)
        if brec["accepted"]:
            account_param_transfer(meter, pcfg.R * st.d_cl)
        sel_cluster = clusters[brec["selected"]]
        rec = dict(
            round=t,
            clusters=clusters,
            val_losses=brec["val_losses"],
            train_losses=brec["train_losses"],
            selected=brec["selected"],
            accepted=brec["accepted"],
            selected_honest=cluster_is_honest(sel_cluster, tm.malicious),
            honest_cluster_exists=any(
                cluster_is_honest(c, tm.malicious) for c in clusters),
            detections=brec["detections"],
            comm=dataclasses.asdict(meter),
        )
        if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
            # only reachable at the pool block's last scanned round: K is
            # the min over lanes of the solo segment length, so a lane's
            # sync rounds never fall mid-block and the stacked carry holds
            # exactly this lane's post-round-t theta
            theta = theta_lane_of()
            with tel.span("round.eval", round=t, job=spec.name):
                rec["test_acc"] = evaluate(
                    spec.module, theta[0], theta[1], spec.data.x_test,
                    spec.data.y_test, pcfg.eval_batch)
        st.hist.rounds.append(rec)
        if st.ckpt_due(t):
            from ..checkpoint import job_checkpoint_metadata, save_checkpoint
            with tel.span("round.checkpoint", round=t, job=spec.name):
                save_checkpoint(spec.checkpoint_path, theta_lane_of(),
                                job_checkpoint_metadata(t, stream_snap,
                                                        job=spec.name))
        tel.record_round(t, rec, job=spec.name)


def _run_bucket(states: List[_JobState], order: List[int], block: int,
                placement: str, lanes: Optional[int], prefetch: int,
                tel) -> None:
    """Execute one shape bucket's jobs through the shared pool program."""
    from ..checkpoint import protocol_state_metadata
    from ..data.pipeline import RoundFeeder
    from .engine import assemble_block

    runnable = [i for i in order if not states[i].terminal]
    if not runnable:
        return
    n_lanes = max(1, min(lanes if lanes else len(runnable), len(runnable)))
    plans = plan_pool(states, order, n_lanes, block)

    st0 = states[runnable[0]]
    runner = protocol_accept_runner(
        st0.spec.module, st0.pcfg.lr, placement, st0.policy,
        st0.pcfg.tamper_check, st0.pcfg.tamper_tol,
        quant=st0.pcfg.comm.quant)

    pcfg0, data0 = st0.pcfg, st0.spec.data
    m_bar = pcfg0.M // pcfg0.R

    def _make_block(b):
        """Assemble one whole-pool block payload: each active lane's K-round
        payload in lane order, every lane consuming ITS OWN job's RNG/key
        streams exactly as the solo block path would; idle lanes copy the
        first active lane's payload as a placeholder (masked on device, no
        stream consumption).  The big leaves (mini-batches) are gathered
        straight into one (J, K, R, M_bar, E, B, ...) host buffer — lane
        views through ``assemble_block(out=...)`` — so the whole pool block
        pays ONE host->device transfer per leaf; the small leaves stack in
        one jitted dispatch.  Stream snapshots for block-end checkpoints are
        captured here, right after each lane's assembly — the fused path
        splits no keys after assembly, so this is the synchronous
        end-of-block stream state (the solo feeder argument)."""
        plan = plans[b]
        xs_j = np.empty((n_lanes, plan.k, pcfg0.R, m_bar, pcfg0.E, pcfg0.B)
                        + data0.x.shape[2:], dtype=data0.x.dtype)
        ys_j = np.empty((n_lanes, plan.k, pcfg0.R, m_bar, pcfg0.E, pcfg0.B)
                        + data0.y.shape[2:], dtype=data0.y.dtype)
        per_lane: List[Optional[tuple]] = [None] * n_lanes
        smalls: List[Optional[list]] = [None] * n_lanes
        for lane, j in enumerate(plan.assign):
            if j < 0:
                continue
            st = states[j]
            st.key, clusters_k, small = assemble_block(
                st.rng, st.key, st.spec.data, st.pcfg, st.tm,
                plan.t0s[lane], plan.k, out=(xs_j[lane], ys_j[lane]))
            snap = None
            if st.spec.checkpoint_path is not None:
                snap = protocol_state_metadata(st.rng, st.key)
            per_lane[lane] = (clusters_k, snap)
            smalls[lane] = small
        first = next(l for l, s in enumerate(smalls) if s is not None)
        for lane in range(n_lanes):
            if smalls[lane] is None:
                xs_j[lane] = xs_j[first]
                ys_j[lane] = ys_j[first]
                smalls[lane] = smalls[first]
        avec_j, keys_j = _stack_small_lanes(tuple(tuple(s) for s in smalls))
        binputs = (jnp.asarray(xs_j), jnp.asarray(ys_j), avec_j, keys_j)
        return per_lane, binputs

    feeder = RoundFeeder(_make_block, 0, len(plans), depth=prefetch,
                         telemetry=tel)
    jobs_done = 0
    theta_j = None
    val_j = None
    prev_assign: Tuple[int, ...] = (-2,) * n_lanes
    try:
        for b, plan in enumerate(plans):
            if prefetch > 0:
                with tel.span("pool.feeder_wait", block=b,
                              depth=feeder.qsize()):
                    per_lane, binputs = feeder.get(b)
            else:
                with tel.span("block.assemble", block=b, k=plan.k):
                    per_lane, binputs = feeder.get(b)
            if plan.assign != prev_assign:
                # lane churn: (re)seat thetas and the stacked validation
                # sets.  Fresh lanes get the job's current theta; idle lanes
                # keep whatever buffer they hold (masked on device).
                if theta_j is None:
                    fill = states[next(j for j in plan.assign if j >= 0)]
                    theta_j = _stack_lanes(tuple(
                        states[j].theta if j >= 0 else fill.theta
                        for j in plan.assign))
                else:
                    for lane, j in enumerate(plan.assign):
                        if j >= 0 and prev_assign[lane] != j:
                            theta_j = _set_lane(theta_j, lane,
                                                states[j].theta)
                fill = states[next(j for j in plan.assign if j >= 0)]
                val_j = _stack_lanes(tuple(
                    (states[j].x0, states[j].y0) if j >= 0
                    else (fill.x0, fill.y0) for j in plan.assign))
                active_j = jnp.asarray([j >= 0 for j in plan.assign])
                prev_assign = plan.assign
            with tel.span("pool.step", block=b, k=plan.k,
                          active=int(np.sum([j >= 0 for j in plan.assign]))) as sp:
                theta_j, fetches = runner.pool_accept_block(
                    theta_j, binputs, val_j, active_j)
                sp.fence(fetches)
            with tel.span("pool.fetch", block=b, k=plan.k):
                fetched = np.asarray(fetches)   # the pool block's ONE sync
            for lane, j in enumerate(plan.assign):
                if j < 0:
                    continue
                st = states[j]
                clusters_k, snap = per_lane[lane]
                records = [dict(val_losses=[float(v) for v in vl],
                                train_losses=[float(v) for v in tl],
                                selected=sel, detections=det, accepted=acc)
                           for vl, tl, sel, det, acc in
                           unpack_block_fetch(fetched[lane], st.pcfg.R)]
                _replay_lane_rounds(
                    st, clusters_k, records, plan.t0s[lane],
                    lambda lane=lane: _lane_slice(theta_j, lane), snap, tel)
                st.t = plan.t0s[lane] + plan.k
                if st.t >= st.pcfg.T:
                    st.theta = _lane_slice(theta_j, lane)
                    jobs_done += 1
            t0s = {states[j].spec.name: plan.t0s[lane]
                   for lane, j in enumerate(plan.assign) if j >= 0}
            tel.emit({"event": "pool_block", "block": b,
                      **pool_gauges(t0s, plan.k, n_lanes, jobs_done,
                                    len(runnable))})
    finally:
        feeder.close()


def run_job_pool(specs: Sequence[JobSpec], *, block: int = 1,
                 placement: str = "vmap", lanes: Optional[int] = None,
                 prefetch: int = 0, telemetry=None,
                 verbose: bool = False) -> Dict[str, History]:
    """Run a pool of Pigeon-SL jobs through shared megabatched device
    programs.  Returns ``{spec.name: History}`` with every job's History
    bit-identical to a solo ``run_pigeon(engine="batched")`` of the same
    spec.

    * ``block`` — rounds fused per device dispatch, per lane (the solo
      ``block=`` knob); each pool block scans ``K = min`` over its active
      lanes' solo segment lengths, so per-lane eval/checkpoint cadences are
      honoured exactly.
    * ``lanes`` — device lanes per bucket (default: one per job).  With
      fewer lanes than jobs, finished jobs free their lane and the queue
      refills it between blocks (elastic recycling).
    * ``placement`` — ``"vmap"`` stacks lanes on one device; ``"sharded"``
      lays the JOB axis over a 1-D device mesh (jobs are embarrassingly
      parallel — no collectives).
    * ``prefetch`` — assemble pool block b+1 on a background thread while
      block b executes (the pool schedule is deterministic up front, so the
      feeder preserves every job's RNG/key order).
    """
    pool = JobPool(specs, block=block, placement=placement)
    tel = resolve_telemetry(telemetry, verbose=verbose, run="pool",
                            jobs=len(specs), block=block,
                            placement=placement, lanes=lanes or 0,
                            buckets=len(pool.buckets()))
    try:
        states: Dict[int, _JobState] = {}
        for bucket in pool.buckets():
            bucket_states: List[_JobState] = []
            for i in bucket:
                policy, tm, pcfg = pool.resolved(i)
                states[i] = _init_job(pool.specs[i], policy, tm, pcfg)
                bucket_states.append(states[i])
            all_states = [states[i] for i in bucket]
            _run_bucket(all_states, list(range(len(all_states))), block,
                        placement, lanes, prefetch, tel)
    finally:
        tel.close()
    return {pool.specs[i].name: states[i].hist for i in
            sorted(states, key=lambda i: i)}
