"""Cluster formation (Section III-B, eq. (1)) and the pigeonhole guarantee."""
from __future__ import annotations

from typing import List, Sequence, Set

import numpy as np


def make_clusters(rng: np.random.Generator, m: int, r: int) -> List[List[int]]:
    """Randomly partition [0, m) into r disjoint clusters of equal size.

    Satisfies (1): pairwise disjoint and covering.  Requires r | m, as in the
    paper (M/R must be a positive integer)."""
    if m % r != 0:
        raise ValueError(f"R={r} must divide M={m} (paper: M_bar = M/R in Z+)")
    perm = rng.permutation(m)
    size = m // r
    return [sorted(perm[i * size : (i + 1) * size].tolist()) for i in range(r)]


def has_honest_cluster(clusters: Sequence[Sequence[int]], malicious: Set[int]) -> bool:
    """The pigeonhole invariant: with |malicious| <= N and R = N + 1 clusters,
    at least one cluster contains no malicious client."""
    return any(all(c not in malicious for c in cluster) for cluster in clusters)


def cluster_is_honest(cluster: Sequence[int], malicious: Set[int]) -> bool:
    return all(c not in malicious for c in cluster)
