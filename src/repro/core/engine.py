"""Batched cluster-parallel protocol engine.

Pigeon-SL's global round trains R = N+1 clusters independently from the same
theta^t — embarrassingly parallel work that the sequential driver in
``protocol.py`` dispatches one ``client_update`` at a time.  This module
stacks the R clusters' sampled batches, per-client attack state and RNG keys
into leading-axis arrays and runs the whole round as ONE compiled program via
the placement-aware :class:`~repro.core.runner.RoundRunner` — ``jax.vmap``
over clusters on one device (``placement="vmap"``) or the cluster axis laid
over a device mesh (``placement="sharded"``), with ``jax.lax.scan`` over each
within-cluster client chain and the shared-set validation forward (plus the
tamper-check activations it produces) mapped alongside.  A second seed level
turns the round program into a multi-seed sweep that advances S whole
protocol replicas in lockstep — nested ``vmap`` on one device, or the S x R
replica grid over a 2-D ``(seed, pod)`` mesh under ``placement="sharded"``.
SplitFed binds the same runner with a per-cluster *parallel* client vmap and
the FedAvg ``combine`` fan-in instead of the client-chain scan.

Equivalence contract with the sequential engine (tested in
``tests/test_engine.py`` / ``tests/test_runner.py``): both engines — under
either placement — consume the numpy batch-sampling RNG and the JAX key
stream in exactly the same order, the attack transforms are
``jnp.where``-masked versions of the same arithmetic, and the CommMeter
accounting goes through the same ``account_client_turn`` helper — so seeded
runs select the same clusters, produce validation losses equal within float
tolerance, and report bit-identical message counts.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..adversary import ThreatModel, resolve_threat_model
from .attacks import HONEST, Attack
from .clustering import cluster_is_honest, make_clusters
from .protocol import (ClientData, CommMeter, History, ProtocolConfig,
                       _count_params, account_client_turn,
                       account_handoff_recheck, account_param_transfer,
                       account_validation, cut_width, sample_batch_idx)
from .runner import (cluster_map, onehot_select, protocol_accept_runner,
                     protocol_round_spec, protocol_runner)
from .split import (SplitModule, client_update_vec_impl,
                    client_update_vec_stats_impl)
from ..telemetry import NULL_SESSION

Pytree = Any


# ---------------------------------------------------------------------------
# host-side assembly: batches, keys and attack state for one round
# ---------------------------------------------------------------------------

def assemble_round_batches(rng: np.random.Generator, data: ClientData,
                           clusters: Sequence[Sequence[int]],
                           pcfg: ProtocolConfig, out=None
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sample every client's (E, B) mini-batches for the round, consuming the
    numpy RNG in the sequential engine's order (cluster-major, then client),
    stacked to (R, M_bar, E, B, ...).  Each gather writes straight into one
    preallocated per-round buffer (``np.take(..., out=...)``), so the host
    pays a single copy per sample instead of the old per-cluster
    ``np.stack`` followed by another stack + device conversion.

    ``out=(xs_view, ys_view)`` writes into caller-provided numpy buffers and
    returns them WITHOUT the device conversion — the round-block assemblers
    pass per-round views of one (K, R, M_bar, ...) block buffer so a K-round
    block pays a single host->device transfer instead of K stacks of
    already-transferred rounds."""
    r, m_bar = len(clusters), len(clusters[0])
    if out is None:
        xs = np.empty((r, m_bar, pcfg.E, pcfg.B) + data.x.shape[2:],
                      dtype=data.x.dtype)
        ys = np.empty((r, m_bar, pcfg.E, pcfg.B) + data.y.shape[2:],
                      dtype=data.y.dtype)
    else:
        xs, ys = out
    for i, cluster in enumerate(clusters):
        for j, client in enumerate(cluster):
            idx = sample_batch_idx(rng, data.x[client].shape[0], pcfg.E, pcfg.B)
            np.take(data.x[client], idx, axis=0, out=xs[i, j])
            np.take(data.y[client], idx, axis=0, out=ys[i, j])
    if out is not None:
        return xs, ys
    return jnp.asarray(xs), jnp.asarray(ys)


@partial(jax.jit, static_argnums=(1, 2))
def _round_client_keys(key: jax.Array, r: int, m_bar: int
                       ) -> Tuple[jax.Array, jax.Array]:
    rows = []
    for _ in range(r):
        key, sub = jax.random.split(key)
        row = []
        for _ in range(m_bar):
            sub, k_j = jax.random.split(sub)
            row.append(k_j)
        rows.append(jnp.stack(row))
    return key, jnp.stack(rows)


def round_client_keys(key: jax.Array, clusters: Sequence[Sequence[int]]
                      ) -> Tuple[jax.Array, jax.Array]:
    """Replicate the sequential engine's key discipline — per cluster
    ``key, sub = split(key)``, then per client ``sub, k_j = split(sub)`` —
    and stack the per-client keys to (R, M_bar, key).  Returns the advanced
    protocol key so both engines stay on the same stream.  The whole split
    chain runs as one jitted call instead of R + M host dispatches."""
    return _round_client_keys(key, len(clusters), len(clusters[0]))


def assemble_round(rng: np.random.Generator, key: jax.Array, data: ClientData,
                   clusters: Sequence[Sequence[int]], pcfg: ProtocolConfig,
                   tm: ThreatModel, t: int, out=None):
    """One round's complete host-side payload: stacked batches, derived
    per-client keys and the round's AttackVec.  THE single copy of the
    RNG/key consumption order — the synchronous path, the RoundFeeder's
    background thread AND the round-block assembler all call this, so the
    bit-identical prefetch-on/off and block-on/off contracts are structural
    rather than test-enforced.  ``out`` is forwarded to
    :func:`assemble_round_batches` (block-buffer views).
    Returns (advanced_key, (xs, ys, avec, keys))."""
    xs, ys = assemble_round_batches(rng, data, clusters, pcfg, out=out)
    key, keys = round_client_keys(key, clusters)
    avec = tm.attack_vec_for_clusters(clusters, t)
    return key, (xs, ys, avec, keys)


# ---------------------------------------------------------------------------
# the compiled round program (single source of truth: core/runner.py)
# ---------------------------------------------------------------------------

def _round_body(module: SplitModule, lr: float, gamma: Pytree, phi: Pytree,
                xs, ys, avec, keys, x0, y0):
    """All R clusters' client chains + shared-set validation — a thin adapter
    over the RoundRunner's :func:`~repro.core.runner.cluster_map` (the one
    copy of the round math) keeping the historical flat signature.

    xs/ys: (R, M_bar, E, B, ...); avec leaves and keys: (R, M_bar, ...).
    Returns (gammas, phis, train_losses (R, M_bar), val_losses (R,),
    val_acts (R, D_o, d_c)) — the R candidate round outcomes.
    """
    (gs, ps), losses, vlosses, vacts = cluster_map(
        protocol_round_spec(module, lr), (gamma, phi),
        (xs, ys, avec, keys), (x0, y0))
    return gs, ps, losses, vlosses, vacts


batched_round = partial(jax.jit, static_argnums=(0, 1))(_round_body)


# ---------------------------------------------------------------------------
# protocol-facing drivers (same result structure as the sequential loops)
# ---------------------------------------------------------------------------

def train_round_batched(module: SplitModule, theta, clusters, data: ClientData,
                        pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                        rng: np.random.Generator, key: jax.Array, meter: CommMeter,
                        d_c: int, x0, y0, placement: str = "vmap",
                        prefetched=None, with_stats: bool = False,
                        telemetry=None
                        ) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """Batched replacement for the sequential per-cluster loop of
    ``run_pigeon``: one compiled call produces all R candidate
    (gamma, phi, val_loss, val_acts) tuples, selection left to the host-side
    reference cascade (``repro.selection.select_host`` — the param-tamper
    path; the default path is :func:`pigeon_round_accept`).  The threat
    model's per-round attack state arrives as AttackVec *data*, so
    heterogeneous mixtures and schedule phases reuse the same compiled
    program; ``placement`` picks the RoundRunner's device mapping
    (single-device vmap or the cluster axis sharded over a host/pod mesh).
    ``prefetched`` carries a round payload assembled ahead of time by the
    RoundFeeder (``data/pipeline.py``) — when given, the RNG/key streams
    were already consumed by the feeder thread in this exact order.
    ``with_stats`` additionally surfaces per-client transmitted-message
    statistics in each result (anomaly-scoring selection policies)."""
    tel = NULL_SESSION if telemetry is None else telemetry
    if prefetched is None:
        with tel.span("round.assemble", round=t):
            key, prefetched = assemble_round(rng, key, data, clusters, pcfg,
                                             tm, t)
    xs, ys, avec, keys = prefetched
    with tel.span("round.step", round=t) as sp:
        (gs, ps), aux, vlosses, vacts = protocol_runner(
            module, pcfg.lr, placement, with_stats,
            quant=pcfg.comm.quant).candidates(
            theta, (xs, ys, avec, keys), (x0, y0))
        sp.fence(vlosses)
    losses, stats = (aux if with_stats else (aux, None))

    d_cl = _count_params(theta[0])
    for cluster in clusters:
        for j in range(len(cluster)):
            account_client_turn(meter, pcfg, d_c, d_cl, handoff=j < len(cluster) - 1)

    losses = np.asarray(losses)
    vlosses = np.asarray(vlosses)
    stats = None if stats is None else np.asarray(stats)
    results = []
    for r, cluster in enumerate(clusters):
        # gamma/phi/vacts stay as views into the stacked arrays; the
        # selection loop materialises only the candidates it inspects
        # (protocol.res_params / res_vacts).
        res = dict(vloss=float(vlosses[r]), cluster=cluster,
                   train_loss=float(np.mean(losses[r])),
                   _stacked=(gs, ps, vacts, r))
        if stats is not None:
            res["msg_stats"] = stats[r]
        results.append(res)
    return key, results


def pigeon_round_accept(module: SplitModule, theta, clusters, data: ClientData,
                        pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                        rng: np.random.Generator, key: jax.Array,
                        meter: CommMeter, d_c: int, x0, y0, policy,
                        placement: str = "vmap", prefetched=None,
                        telemetry=None):
    """The default batched round: training, validation AND the whole
    acceptance cascade (policy score -> rank -> handoff verify -> commit)
    in one compiled program, with a single stacked host fetch.  Returns
    ``(key, theta', record)`` where ``record`` carries the History fields
    (val_losses / train_losses / selected / detections / accepted).

    Only callable when the threat model mounts no handoff (param-tamper)
    attacks — those split the protocol key per *visited* candidate, which is
    inherently host-sequenced (``repro.selection.select_host``)."""
    from ..selection import unpack_fetch
    assert not tm.has_param_tamper, \
        "param-tamper threat models must use the host selection cascade"
    tel = NULL_SESSION if telemetry is None else telemetry
    if prefetched is None:
        with tel.span("round.assemble", round=t):
            key, prefetched = assemble_round(rng, key, data, clusters, pcfg,
                                             tm, t)
    runner = protocol_accept_runner(module, pcfg.lr, placement, policy,
                                    pcfg.tamper_check, pcfg.tamper_tol,
                                    quant=pcfg.comm.quant)
    with tel.span("round.step", round=t) as sp:
        theta_next, fetch = runner.accept(theta, prefetched, (x0, y0))
        # fence the fetch only: the step span absorbs the device round
        # (block_until_ready waits, it does not transfer), leaving the fetch
        # span below with just the D2H copy — still ONE host sync per round
        sp.fence(fetch)

    d_cl = _count_params(theta[0])
    for cluster in clusters:
        for j in range(len(cluster)):
            account_client_turn(meter, pcfg, d_c, d_cl,
                                handoff=j < len(cluster) - 1)

    with tel.span("round.fetch", round=t):
        vlosses, tlosses, selected, detections, accepted = unpack_fetch(
            np.asarray(fetch), len(clusters))      # the round's one host sync
    with tel.span("round.select", round=t):
        # Table I accounting for the handoff re-checks: one R-recipient
        # re-transmission per visited candidate, exactly as the host cascade
        # charges per visit (detections failures + the accepted one).
        if pcfg.tamper_check:
            visited = detections + (1 if accepted else 0)
            account_handoff_recheck(meter, pcfg, int(x0.shape[0]), d_c,
                                    visited)
        record = dict(val_losses=[float(v) for v in vlosses],
                      train_losses=[float(v) for v in tlosses],
                      selected=selected, detections=detections,
                      accepted=accepted)
    return key, theta_next, record


def train_cluster_batched(module: SplitModule, theta, cluster, data: ClientData,
                          pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                          rng: np.random.Generator, key: jax.Array,
                          meter: CommMeter, d_c: int
                          ) -> Tuple[jax.Array, Pytree, Pytree, float]:
    """One cluster's client chain as a single compiled call (used for the
    Pigeon-SL+ sub-rounds; always the vmap placement — a single cluster has
    no cluster axis to shard).  Key/RNG consumption matches the sequential
    ``split(key)`` + ``train_cluster`` pair exactly."""
    key, payload = assemble_round(rng, key, data, [cluster], pcfg, tm, t)
    (gs, ps), losses, _, _ = protocol_runner(
        module, pcfg.lr, "vmap", quant=pcfg.comm.quant).candidates(
        theta, payload,
        (jnp.asarray(data.x0[:1]), jnp.asarray(data.y0[:1])))
    d_cl = _count_params(theta[0])
    for j in range(len(cluster)):
        account_client_turn(meter, pcfg, d_c, d_cl, handoff=j < len(cluster) - 1)
    g = jax.tree.map(lambda a: a[0], gs)
    p = jax.tree.map(lambda a: a[0], ps)
    return key, g, p, float(np.mean(np.asarray(losses)))


# ---------------------------------------------------------------------------
# SplitFed: all M clients update in parallel (no within-cluster chain)
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def splitfed_round_spec(module: SplitModule, lr: float,
                        with_stats: bool = False,
                        quant: Optional[str] = None) -> "RoundSpec":
    """SplitFed's per-cluster programs as a RoundRunner binding: every client
    trains *in parallel* from the cluster's incoming theta (vmap over the
    client axis, vs the Pigeon chain's scan), the RoundSpec ``combine`` hook
    FedAvg-fans the per-client results into the cluster model, and shared-set
    validation is identical to the Pigeon spec.  Binding through the runner
    gives SplitFed both placements, the prefetch pipeline and the pluggable
    selection policies for free — there is no bespoke SplitFed round body any
    more.  No ``handoff_acts`` hook: SplitFed has no chained parameter
    handoff, so the fused cascade's verify stage stays disabled."""
    from .runner import RoundSpec

    def train_cluster(theta, inputs):
        xs_c, ys_c, av_c, keys_c = inputs
        gamma, phi = theta

        def per_client(x, y, av, k):
            if with_stats:
                g, p, loss, stats = client_update_vec_stats_impl(
                    module, av, gamma, phi, (x, y), lr, k, quant=quant)
                return (g, p), (loss, stats)
            g, p, loss = client_update_vec_impl(module, av, gamma, phi,
                                                (x, y), lr, k, quant=quant)
            return (g, p), loss

        (gs, ps), aux = jax.vmap(per_client)(xs_c, ys_c, av_c, keys_c)
        return (gs, ps), aux

    def fedavg(theta):
        return jax.tree.map(lambda a: jnp.mean(a, axis=0), theta)

    def validate(theta, val):
        g, p = theta
        x0, y0 = val
        acts = module.client_forward(g, x0)
        # val_aux None: SplitFed has no handoff tamper check, so the
        # (R, D_o, d_c) activation stack would be dead weight every round
        return module.ap_loss(p, acts, y0), None

    def validate_sharded(theta, val, k):
        from .runner import sharded_validation_losses
        g, p = theta
        x0, y0 = val
        acts = module.client_forward(g, x0)
        shard_losses = sharded_validation_losses(module, p, acts, y0, k)
        return module.ap_loss(p, acts, y0), shard_losses, None

    from .runner import make_train_summary
    return RoundSpec(
        train_cluster, validate, combine=fedavg,
        validate_sharded=validate_sharded,
        train_summary=make_train_summary(with_stats),
        message_stats=(lambda aux: aux[1]) if with_stats else None)


@lru_cache(maxsize=None)
def splitfed_runner(module: SplitModule, lr: float, placement: str = "vmap",
                    with_stats: bool = False, quant: Optional[str] = None):
    """Cached per (module, lr, placement, stats, quant), like
    :func:`protocol_runner`."""
    from .runner import RoundRunner
    return RoundRunner(splitfed_round_spec(module, lr, with_stats, quant),
                       placement=placement)


@lru_cache(maxsize=None)
def splitfed_accept_runner(module: SplitModule, lr: float, placement: str,
                           select, quant: Optional[str] = None):
    """SplitFed's fused-selection runner: the policy cascade with the verify
    stage off (no chained handoff to tamper with)."""
    from .runner import RoundRunner, VerifyConfig
    spec = splitfed_round_spec(module, lr,
                               with_stats=select.needs_message_stats,
                               quant=quant)
    return RoundRunner(spec, placement=placement, select=select,
                       verify=VerifyConfig(enabled=False))


@partial(jax.jit, static_argnums=(1, 2))
def _splitfed_keys(key: jax.Array, r: int, m_bar: int
                   ) -> Tuple[jax.Array, jax.Array]:
    rows = []
    for _ in range(r):
        row = []
        for _ in range(m_bar):
            key, sub = jax.random.split(key)
            row.append(sub)
        rows.append(jnp.stack(row))
    return key, jnp.stack(rows)


def splitfed_keys(key: jax.Array, clusters: Sequence[Sequence[int]]
                  ) -> Tuple[jax.Array, jax.Array]:
    """SplitFed's sequential loop splits the running protocol key once per
    client (cluster-major order) with no per-cluster sub-stream."""
    return _splitfed_keys(key, len(clusters), len(clusters[0]))


def assemble_splitfed_round(rng: np.random.Generator, key: jax.Array,
                            data: ClientData,
                            clusters: Sequence[Sequence[int]],
                            pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                            out=None):
    """One SplitFed round's host-side payload, consuming the numpy RNG and
    the key stream in the sequential loop's order (cluster-major batch
    sampling; one key split per client, no per-cluster sub-stream).  SplitFed
    sampling never depends on the previous round's selection, so the
    RoundFeeder can run this at any depth — no phase-boundary fallback.
    ``out`` is forwarded to :func:`assemble_round_batches` (block-buffer
    views).  Returns (advanced_key, (xs, ys, avec, keys))."""
    xs, ys = assemble_round_batches(rng, data, clusters, pcfg, out=out)
    key, keys = splitfed_keys(key, clusters)
    avec = tm.attack_vec_for_clusters(clusters, t)
    return key, (xs, ys, avec, keys)


def splitfed_round_batched(module: SplitModule, theta, clusters, data: ClientData,
                           pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                           rng: np.random.Generator,
                           key: jax.Array, x0, y0, placement: str = "vmap",
                           prefetched=None, with_stats: bool = False,
                           telemetry=None
                           ) -> Tuple[jax.Array, List[Dict[str, Any]]]:
    """Batched SplitFed round through the placement-aware RoundRunner (the
    FedAvg combine hook makes the cluster model the mean of its clients),
    selection left to the caller — the host reference path.
    ``prefetched`` carries a payload pre-assembled by the RoundFeeder — the
    feeder thread already consumed the RNG/key streams in this order."""
    tel = NULL_SESSION if telemetry is None else telemetry
    if prefetched is None:
        with tel.span("round.assemble", round=t):
            key, prefetched = assemble_splitfed_round(rng, key, data,
                                                      clusters, pcfg, tm, t)
    xs, ys, avec, keys = prefetched
    with tel.span("round.step", round=t) as sp:
        (g_avg, p_avg), aux, vlosses, _ = splitfed_runner(
            module, pcfg.lr, placement, with_stats,
            quant=pcfg.comm.quant).candidates(
            theta, (xs, ys, avec, keys), (x0, y0))
        sp.fence(vlosses)
    stats = np.asarray(aux[1]) if with_stats else None
    vlosses = np.asarray(vlosses)
    results = []
    for r, cluster in enumerate(clusters):
        res = dict(vloss=float(vlosses[r]), cluster=cluster,
                   _stacked=(g_avg, p_avg, None, r))
        if stats is not None:
            res["msg_stats"] = stats[r]
        results.append(res)
    return key, results


def splitfed_round_accept(module: SplitModule, theta, clusters,
                          data: ClientData, pcfg: ProtocolConfig,
                          tm: ThreatModel, t: int, rng: np.random.Generator,
                          key: jax.Array, x0, y0, policy,
                          placement: str = "vmap", prefetched=None,
                          telemetry=None):
    """SplitFed's default batched round: FedAvg per cluster + the policy
    selection cascade in one compiled program, one stacked host fetch.
    Returns ``(key, theta', record)`` like :func:`pigeon_round_accept`
    (``detections`` always 0 and ``accepted`` always True — no handoff
    verify stage)."""
    from ..selection import unpack_fetch
    tel = NULL_SESSION if telemetry is None else telemetry
    if prefetched is None:
        with tel.span("round.assemble", round=t):
            key, prefetched = assemble_splitfed_round(rng, key, data,
                                                      clusters, pcfg, tm, t)
    runner = splitfed_accept_runner(module, pcfg.lr, placement, policy,
                                    quant=pcfg.comm.quant)
    with tel.span("round.step", round=t) as sp:
        theta_next, fetch = runner.accept(theta, prefetched, (x0, y0))
        sp.fence(fetch)
    with tel.span("round.fetch", round=t):
        vlosses, tlosses, selected, detections, accepted = unpack_fetch(
            np.asarray(fetch), len(clusters))
    record = dict(val_losses=[float(v) for v in vlosses],
                  train_losses=[float(v) for v in tlosses],
                  selected=selected, detections=detections, accepted=accepted)
    return key, theta_next, record


# ---------------------------------------------------------------------------
# round-block execution: K host-assembled rounds, one scanned device program
# ---------------------------------------------------------------------------

@jax.jit
def _stack_tree(payloads):
    return jax.tree.map(lambda *ls: jnp.stack(ls), *payloads)


def stack_payloads(payloads):
    """Stack K per-round payload pytrees along a new leading round axis —
    the xs of the RoundRunner's ``lax.scan`` block entries (step i slices
    back exactly round i's payload).  Jitted so the whole pytree stacks in
    ONE dispatch (an eager per-leaf ``jnp.stack`` costs a dispatch per leaf,
    which at small per-round compute eats the fusion win)."""
    return _stack_tree(tuple(payloads))


def assemble_block(rng: np.random.Generator, key: jax.Array, data: ClientData,
                   pcfg: ProtocolConfig, tm: ThreatModel, t0: int, k: int,
                   out=None):
    """Host-side payload for a K-round block starting at round ``t0``:
    cluster partitions, stacked mini-batches, derived per-client keys and
    attack state for rounds ``t0 .. t0+k-1``, stacked to a leading K axis.

    Consumes the numpy RNG and the JAX key stream in EXACTLY the synchronous
    per-round order — for each round in turn: the cluster partition draw,
    then that round's :func:`assemble_round` — so after assembly both streams
    sit precisely where the per-round loop would leave them at the end of
    round ``t0+k-1``.  (The fused acceptance path splits no keys after
    assembly, which is why a single post-block stream snapshot gives the
    same crash-atomic resume semantics as per-round checkpoints.)

    Returns ``(advanced_key, clusters_k, block_inputs)`` where ``clusters_k``
    is the K per-round cluster partitions (the host replay needs them for
    History/honesty/CommMeter bookkeeping).

    ``out=(xs_k, ys_k)`` writes the batches into caller-provided numpy
    buffers — e.g. one lane view of a job pool's ``(J, K, ...)`` block
    buffer — and returns the SMALL leaves raw (a list of K ``(avec, keys)``
    payloads, no stacking, no device conversion): the caller owns both the
    transfer and the stack, so a J-lane pool block pays one host->device
    copy per leaf instead of J."""
    return _assemble_block_with(assemble_round, rng, key, data, pcfg, tm,
                                t0, k, out=out)


def assemble_splitfed_block(rng: np.random.Generator, key: jax.Array,
                            data: ClientData, pcfg: ProtocolConfig,
                            tm: ThreatModel, t0: int, k: int):
    """SplitFed variant of :func:`assemble_block` (cluster-major batch
    sampling, one key split per client — see
    :func:`assemble_splitfed_round`)."""
    return _assemble_block_with(assemble_splitfed_round, rng, key, data,
                                pcfg, tm, t0, k)


def _assemble_block_with(assemble_one, rng: np.random.Generator,
                         key: jax.Array, data: ClientData,
                         pcfg: ProtocolConfig, tm: ThreatModel,
                         t0: int, k: int, out=None):
    """Shared K-round assembly: the mini-batches of all K rounds are gathered
    into ONE preallocated (K, R, M_bar, E, B, ...) host buffer (per-round
    ``out=`` views of it), so the block pays a single host->device transfer
    instead of K transfers followed by a device-side re-stack; the small
    leaves (AttackVec state, per-client keys) are stacked on device.

    With ``out=(xs_k, ys_k)`` the caller provides the buffers and gets the
    small leaves back raw (list of K ``(avec, keys)``) — no stacking, no
    device conversion (see :func:`assemble_block`)."""
    m_bar = pcfg.M // pcfg.R
    if out is None:
        xs_k = np.empty((k, pcfg.R, m_bar, pcfg.E, pcfg.B) + data.x.shape[2:],
                        dtype=data.x.dtype)
        ys_k = np.empty((k, pcfg.R, m_bar, pcfg.E, pcfg.B) + data.y.shape[2:],
                        dtype=data.y.dtype)
    else:
        xs_k, ys_k = out
    clusters_k, small = [], []
    for i in range(k):
        clusters = make_clusters(rng, pcfg.M, pcfg.R)
        key, (_, _, avec, keys) = assemble_one(rng, key, data, clusters,
                                               pcfg, tm, t0 + i,
                                               out=(xs_k[i], ys_k[i]))
        clusters_k.append(clusters)
        small.append((avec, keys))
    if out is not None:
        return key, clusters_k, small
    avec_k, keys_k = stack_payloads(small)
    return key, clusters_k, (jnp.asarray(xs_k), jnp.asarray(ys_k),
                             avec_k, keys_k)


def pigeon_block_accept(module: SplitModule, theta, clusters_k,
                        pcfg: ProtocolConfig, tm: ThreatModel, t0: int,
                        block_inputs, x0, y0, policy, placement: str = "vmap",
                        telemetry=None):
    """K consecutive fused acceptance rounds as ONE compiled ``lax.scan``
    program with a single stacked ``(K, 2R+3)`` host fetch — the round-block
    variant of :func:`pigeon_round_accept`.  Returns ``(theta_next,
    records)`` with one per-round record dict (the History fields:
    val_losses / train_losses / selected / detections / accepted) per
    scanned round.

    Unlike the per-round path, NO CommMeter accounting happens here: the
    driver replays client turns, validation pushes, tamper re-checks and the
    winner broadcast per round from ``records`` + ``clusters_k`` (the counts
    are analytic in the record fields, so the replay is bit-identical to
    per-round metering by construction).  Same precondition as the per-round
    accept: no param-tamper threat models (those are host-sequenced and pin
    ``block=1``)."""
    from ..selection import unpack_block_fetch
    assert not tm.has_param_tamper, \
        "param-tamper threat models must use the host selection cascade"
    tel = NULL_SESSION if telemetry is None else telemetry
    runner = protocol_accept_runner(module, pcfg.lr, placement, policy,
                                    pcfg.tamper_check, pcfg.tamper_tol,
                                    quant=pcfg.comm.quant)
    k = len(clusters_k)
    with tel.span("block.step", round=t0, k=k) as sp:
        theta_next, fetches = runner.accept_block(theta, block_inputs,
                                                  (x0, y0))
        sp.fence(fetches)
    with tel.span("block.fetch", round=t0, k=k):
        fetched = np.asarray(fetches)          # the block's ONE host sync
    records = []
    for vlosses, tlosses, selected, detections, accepted in \
            unpack_block_fetch(fetched, len(clusters_k[0])):
        records.append(dict(val_losses=[float(v) for v in vlosses],
                            train_losses=[float(v) for v in tlosses],
                            selected=selected, detections=detections,
                            accepted=accepted))
    return theta_next, records


def splitfed_block_accept(module: SplitModule, theta, clusters_k,
                          pcfg: ProtocolConfig, t0: int, block_inputs, x0, y0,
                          policy, placement: str = "vmap", telemetry=None):
    """SplitFed round-block: K FedAvg + selection-cascade rounds as one
    scanned program, one stacked fetch — the block variant of
    :func:`splitfed_round_accept` (verify stage off: no chained handoff).
    Accounting is the driver's analytic per-round replay
    (``account_splitfed_round``), exactly as in per-round mode."""
    from ..selection import unpack_block_fetch
    tel = NULL_SESSION if telemetry is None else telemetry
    runner = splitfed_accept_runner(module, pcfg.lr, placement, policy,
                                    quant=pcfg.comm.quant)
    k = len(clusters_k)
    with tel.span("block.step", round=t0, k=k) as sp:
        theta_next, fetches = runner.accept_block(theta, block_inputs,
                                                  (x0, y0))
        sp.fence(fetches)
    with tel.span("block.fetch", round=t0, k=k):
        fetched = np.asarray(fetches)
    records = []
    for vlosses, tlosses, selected, detections, accepted in \
            unpack_block_fetch(fetched, len(clusters_k[0])):
        records.append(dict(val_losses=[float(v) for v in vlosses],
                            train_losses=[float(v) for v in tlosses],
                            selected=selected, detections=detections,
                            accepted=accepted))
    return theta_next, records


# ---------------------------------------------------------------------------
# multi-seed sweep: whole protocol replicas over (seed, cluster)
# ---------------------------------------------------------------------------

def sweep_round(module: SplitModule, lr: float, theta_s, inputs, val,
                placement: str = "vmap", policy=None,
                quant: Optional[str] = None):
    """One global round for S independent protocol replicas through the
    RoundRunner's sweep entry: per seed, the cluster-parallel round + policy
    selection + winner carry, all inside one compiled program.  Under
    ``placement="sharded"`` the S x R replica grid is laid over a 2-D
    ``(seed, pod)`` device mesh (per-seed selection stays on device: the
    cluster-axis feature all-gathers and the winner psum are the only
    collectives).  Returns ``(theta_S, train_aux_SRM, vlosses_SR,
    sels_S)``."""
    with_stats = policy is not None and policy.needs_message_stats
    return protocol_runner(module, lr, placement, with_stats,
                           policy, quant).sweep(theta_s, inputs, val)


@lru_cache(maxsize=None)
def _sweep_count(module: SplitModule):
    """Jitted seed-vmapped correct-prediction count, cached per module.
    Counting on device avoids transferring the full (S, b, classes) logits
    tensor to the host for every evaluation batch; the integer counts are
    the same, so the resulting accuracies are bit-identical."""
    @jax.jit
    def count(gammas, phis, xb, yb):
        logits = jax.vmap(module.predict, in_axes=(0, 0, None))(
            gammas, phis, xb)                              # (S, b, classes)
        return jnp.sum(jnp.argmax(logits, axis=-1) == yb[None],
                       axis=-1, dtype=jnp.int32)           # (S,)
    return count


def evaluate_sweep(module: SplitModule, gammas, phis, x_test: np.ndarray,
                   y_test: np.ndarray, batch: int = 500) -> np.ndarray:
    """Per-seed test accuracy: ``module.predict`` vmapped over the seed axis,
    batched over the test set exactly like ``protocol.evaluate``.  Counts
    accumulate on device; the evaluation's only host transfer is one final
    (S,) int32 vector."""
    count = _sweep_count(module)
    correct = None
    total = 0
    for i in range(0, x_test.shape[0], batch):
        xb = jnp.asarray(x_test[i : i + batch])
        yb = jnp.asarray(y_test[i : i + batch])
        c = count(gammas, phis, xb, yb)
        correct = c if correct is None else correct + c
        total += int(y_test[i : i + batch].shape[0])
    correct = np.asarray(correct)              # the evaluation's one fetch
    return correct / float(total)


def run_pigeon_sweep(module: SplitModule, data: ClientData, pcfg: ProtocolConfig,
                     malicious: Optional[Set[int]] = None, attack: Attack = HONEST,
                     seeds: Sequence[int] = (0, 1, 2),
                     verbose: bool = False, placement: str = "vmap",
                     threat_model: Optional[ThreatModel] = None,
                     selection="argmin",
                     quant: Optional[str] = None,
                     telemetry=None, block: int = 1) -> List[History]:
    """S whole Pigeon-SL replicas (different seeds) advanced in lockstep: one
    compiled call per global round trains S x R clusters and performs the
    per-seed argmin selection on device.  ``placement="vmap"`` runs the
    (seed, cluster) grid as two nested vmaps on one device;
    ``placement="sharded"`` lays it over a 2-D ``(seed, pod)`` device mesh
    (auto-factorised to cover the most devices — see
    :func:`repro.core.runner.sweep_mesh`), with the per-seed argmin still on
    device.

    Selection happens inside the compiled program under the policy named by
    ``selection`` (``repro.selection``; per-seed scores, default argmin), so
    the host-side param-tamper handoff check is not modelled — the sweep
    supports the honest case and every message-level threat model
    (heterogeneous mixtures and schedules included).  Returns one
    ``History`` per seed (CommMeter accounting is analytic and identical
    across seeds).

    ``block > 1`` chains up to ``block`` consecutive global rounds as one
    scanned device program with a single stacked host fetch per block
    (:meth:`repro.core.runner.RoundRunner.sweep_block`); blocks break at
    eval sync rounds (``pcfg.eval_every``) so per-seed evaluation still sees
    every required intermediate state, and the per-round Histories replayed
    from the block fetch are bit-identical to ``block=1``.
    """
    from ..selection import resolve_policy
    from .comm import CommConfig
    from .protocol import check_block
    from .runner import check_placement
    check_placement(placement)
    block = check_block(block, "batched", eval_every=pcfg.eval_every)
    if quant is not None:
        pcfg = dataclasses.replace(pcfg, comm=CommConfig(quant=quant))
    policy = resolve_policy(selection)
    tm = resolve_threat_model(malicious, attack, threat_model)
    if tm.has_param_tamper:
        raise ValueError("run_pigeon_sweep does not model the param-tamper "
                         "handoff check; use run_pigeon(engine=...) per seed")
    seeds = tuple(int(s) for s in seeds)
    rngs = [np.random.default_rng(s) for s in seeds]
    keys, k0s = [], []
    for s in seeds:
        k, k0 = jax.random.split(jax.random.PRNGKey(s))
        keys.append(k)
        k0s.append(k0)
    thetas = jax.vmap(module.init)(jnp.stack(k0s))
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)
    d_o = data.x0.shape[0]
    d_cl = _count_params(jax.tree.map(lambda a: a[0], thetas[0]))
    d_c = cut_width(module, jax.tree.map(lambda a: a[0], thetas[0]), data.x0)
    hists = [History() for _ in seeds]
    from ..telemetry import resolve_telemetry
    tel = resolve_telemetry(telemetry, run="sweep", placement=placement,
                            T=pcfg.T, M=pcfg.M, R=pcfg.R, seeds=list(seeds),
                            selection=policy.name)

    if block > 1:
        # Round-block execution: chain K global rounds as one scanned sweep
        # program (RoundRunner.sweep_block) with a single stacked host fetch,
        # then replay the per-seed History records from it.  Per-round
        # assembly order per seed (cluster draw, then batches/keys) is
        # preserved exactly, so the trajectories are bit-identical to
        # block=1.
        from ..data.pipeline import plan_blocks
        runner = protocol_runner(module, pcfg.lr, placement,
                                 policy.needs_message_stats, policy,
                                 pcfg.comm.quant)
        segments = plan_blocks(0, pcfg.T, block,
                               lambda t: (t % pcfg.eval_every == 0
                                          or t == pcfg.T - 1))
        try:
            for t0, k in segments:
                tel.profile_tick(t0)
                with tel.span("block.assemble", round=t0, k=k):
                    clusters_sk, payloads = [], []
                    for i in range(k):
                        clusters_s = [make_clusters(rngs[j], pcfg.M, pcfg.R)
                                      for j in range(len(seeds))]
                        xs, ys, key_rows, avecs = [], [], [], []
                        for j in range(len(seeds)):
                            keys[j], (x_j, y_j, avec_j, krow) = assemble_round(
                                rngs[j], keys[j], data, clusters_s[j], pcfg,
                                tm, t0 + i)
                            xs.append(x_j)
                            ys.append(y_j)
                            key_rows.append(krow)
                            avecs.append(avec_j)
                        avec = jax.tree.map(lambda *ls: jnp.stack(ls), *avecs)
                        payloads.append((jnp.stack(xs), jnp.stack(ys), avec,
                                         jnp.stack(key_rows)))
                        clusters_sk.append(clusters_s)
                    block_inputs = stack_payloads(payloads)
                with tel.span("block.step", round=t0, k=k) as sp:
                    thetas, (vl_k, tl_k, sels_k) = runner.sweep_block(
                        thetas, block_inputs, (x0, y0))
                    sp.fence(sels_k)
                with tel.span("block.fetch", round=t0, k=k):
                    vl_k = np.asarray(vl_k)      # (K, S, R)
                    tl_k = np.asarray(tl_k)      # (K, S, R)
                    sels_k = np.asarray(sels_k)  # (K, S)
                gammas, phis = thetas
                for i in range(k):
                    t = t0 + i
                    clusters_s = clusters_sk[i]
                    meter = CommMeter()
                    for cluster in clusters_s[0]:
                        for j in range(len(cluster)):
                            account_client_turn(meter, pcfg, d_c, d_cl,
                                                handoff=j < len(cluster) - 1)
                        account_validation(meter, d_o, d_c)
                    if pcfg.tamper_check:
                        account_handoff_recheck(meter, pcfg, d_o, d_c,
                                                visited=1)
                    account_param_transfer(meter, pcfg.R * d_cl)
                    accs = None
                    if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                        # plan_blocks ends every block at an eval sync round,
                        # so thetas here is exactly the post-round-t state
                        with tel.span("round.eval", round=t):
                            accs = evaluate_sweep(module, gammas, phis,
                                                  data.x_test, data.y_test,
                                                  pcfg.eval_batch)
                    for j in range(len(seeds)):
                        sel = int(sels_k[i][j])
                        rec = dict(
                            round=t,
                            clusters=clusters_s[j],
                            val_losses=[float(v) for v in vl_k[i][j]],
                            train_losses=[float(v) for v in tl_k[i][j]],
                            selected=sel,
                            selected_honest=cluster_is_honest(
                                clusters_s[j][sel], tm.malicious),
                            honest_cluster_exists=any(
                                cluster_is_honest(c, tm.malicious)
                                for c in clusters_s[j]),
                            comm=dataclasses.asdict(meter),
                        )
                        if accs is not None:
                            rec["test_acc"] = float(accs[j])
                        hists[j].rounds.append(rec)
                        tel.record_round(t, rec, seed=seeds[j])
                    if verbose:
                        acc_str = ("" if accs is None
                                   else " acc=" + "/".join(f"{a:.3f}"
                                                           for a in accs))
                        print(f"[sweep] t={t:3d} sel={sels_k[i].tolist()}"
                              f"{acc_str}")
        finally:
            tel.close()
        return hists

    try:
        for t in range(pcfg.T):
            tel.profile_tick(t)
            with tel.span("round.assemble", round=t):
                clusters_s = [make_clusters(rngs[i], pcfg.M, pcfg.R)
                              for i in range(len(seeds))]
                xs, ys, key_rows, avecs = [], [], [], []
                for i in range(len(seeds)):
                    keys[i], (x_i, y_i, avec_i, krow) = assemble_round(
                        rngs[i], keys[i], data, clusters_s[i], pcfg, tm, t)
                    xs.append(x_i)
                    ys.append(y_i)
                    key_rows.append(krow)
                    avecs.append(avec_i)
                avec = jax.tree.map(lambda *ls: jnp.stack(ls), *avecs)
            with tel.span("round.step", round=t) as sp:
                thetas, aux, vlosses, sels = sweep_round(
                    module, pcfg.lr, thetas,
                    (jnp.stack(xs), jnp.stack(ys), avec,
                     jnp.stack(key_rows)),
                    (x0, y0), placement, policy, pcfg.comm.quant)
                sp.fence(vlosses)
            gammas, phis = thetas
            tloss_rm = aux[0] if isinstance(aux, tuple) else aux
            tlosses = jnp.mean(tloss_rm, axis=-1)   # (S, R): mean over clients

            meter = CommMeter()
            for cluster in clusters_s[0]:
                for j in range(len(cluster)):
                    account_client_turn(meter, pcfg, d_c, d_cl,
                                        handoff=j < len(cluster) - 1)
                account_validation(meter, d_o, d_c)
            if pcfg.tamper_check:
                # run_pigeon inspects exactly one candidate per round in the
                # honest/message-attack cases the sweep supports: the
                # next-round first clients' re-transmission of its handoff
                # activations.
                account_handoff_recheck(meter, pcfg, d_o, d_c, visited=1)
            account_param_transfer(meter, pcfg.R * d_cl)

            vlosses = np.asarray(vlosses)
            sels = np.asarray(sels)
            tlosses = np.asarray(tlosses)
            accs = None
            if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                with tel.span("round.eval", round=t):
                    accs = evaluate_sweep(module, gammas, phis, data.x_test,
                                          data.y_test, pcfg.eval_batch)
            for i in range(len(seeds)):
                sel = int(sels[i])
                rec = dict(
                    round=t,
                    clusters=clusters_s[i],
                    val_losses=[float(v) for v in vlosses[i]],
                    train_losses=[float(v) for v in tlosses[i]],
                    selected=sel,
                    selected_honest=cluster_is_honest(clusters_s[i][sel],
                                                      tm.malicious),
                    honest_cluster_exists=any(
                        cluster_is_honest(c, tm.malicious)
                        for c in clusters_s[i]),
                    comm=dataclasses.asdict(meter),
                )
                if accs is not None:
                    rec["test_acc"] = float(accs[i])
                hists[i].rounds.append(rec)
                tel.record_round(t, rec, seed=seeds[i])
            if verbose:
                acc_str = ("" if accs is None
                           else " acc=" + "/".join(f"{a:.3f}" for a in accs))
                print(f"[sweep] t={t:3d} sel={sels.tolist()}{acc_str}")
    finally:
        tel.close()
    return hists
