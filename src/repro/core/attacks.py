"""Thin compatibility shim over ``repro.adversary``.

The attack machinery — family registry, static reference transforms, the
extended vmappable ``AttackVec`` and its compilation, schedules and threat
models — lives in the :mod:`repro.adversary` package.  This module keeps the
historical ``repro.core.attacks`` import surface (and the legacy
``attack_vec_for_clusters(attack, clusters, malicious)`` helper) working.
"""
from __future__ import annotations

from typing import Sequence, Set

from ..adversary import (ACTIVATION, BACKDOOR, GRAD_NOISE, GRAD_SCALE,
                         GRADIENT, HONEST, KINDS, LABEL_FLIP, NONE,
                         PARAM_TAMPER, REPLAY, STEALTH, Attack, AttackVec,
                         attack_vec, attack_vec_grid, flip_labels,
                         flip_labels_vec, poison_inputs, poison_inputs_vec,
                         stealth, tamper_activation, tamper_activation_vec,
                         tamper_gradient, tamper_gradient_vec, tamper_params)
from ..adversary.threat_model import ThreatModel

__all__ = [
    "NONE", "LABEL_FLIP", "ACTIVATION", "GRADIENT", "PARAM_TAMPER",
    "BACKDOOR", "GRAD_SCALE", "GRAD_NOISE", "REPLAY", "STEALTH", "KINDS",
    "Attack", "HONEST", "stealth", "AttackVec", "attack_vec",
    "attack_vec_grid", "attack_vec_for_clusters",
    "poison_inputs", "flip_labels", "tamper_activation", "tamper_gradient",
    "tamper_params", "poison_inputs_vec", "flip_labels_vec",
    "tamper_activation_vec", "tamper_gradient_vec",
]


def attack_vec_for_clusters(attack: Attack, clusters: Sequence[Sequence[int]],
                            malicious: Set[int]) -> AttackVec:
    """(R, M_bar)-leaved AttackVec for one round's cluster partition — the
    legacy homogeneous-population entry point (always-on schedule)."""
    return ThreatModel.from_legacy(set(malicious), attack) \
        .attack_vec_for_clusters(clusters, 0)
