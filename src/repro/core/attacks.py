"""The three attack models of Section II (and the Section III-C parameter
tampering used against the validation mechanism).

All three are implemented exactly as parameterised in Section V-A:

  * label flipping       y -> (y + 3) mod n_classes
  * activation tampering g -> 0.1 * g + 0.9 * n~,  n~ = (|g|/|n|) n,
                          n ~ N(0, I)  (norm-matched noise)
  * gradient tampering   grad_c -> -grad_c  (sign reversal)

``Attack`` is a frozen (hashable) dataclass so it can be a static jit arg —
each attack kind compiles its own specialised update step, mirroring the fact
that honest and malicious clients run different computations.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np

NONE = "none"
LABEL_FLIP = "label_flip"
ACTIVATION = "activation"
GRADIENT = "gradient"
PARAM_TAMPER = "param_tamper"       # Section III-C: tampering the handed-off params

KINDS = (NONE, LABEL_FLIP, ACTIVATION, GRADIENT, PARAM_TAMPER)


@dataclasses.dataclass(frozen=True)
class Attack:
    kind: str = NONE
    label_shift: int = 3
    act_keep: float = 0.1            # fraction of the true activation kept
    param_scale: float = 5.0         # multiplier used by the param-tamper attack

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


HONEST = Attack(NONE)


def flip_labels(attack: Attack, y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    if attack.kind != LABEL_FLIP:
        return y
    return (y + attack.label_shift) % n_classes


def _noise_blend(acts: jnp.ndarray, key: jax.Array, keep) -> jnp.ndarray:
    """Keep a ``keep`` fraction of the true cut activation and replace the
    rest with Gaussian noise norm-matched per sample (leading axis = batch).
    Shared by the static and vectorised tamper transforms so the blend
    arithmetic has a single source of truth."""
    n = jax.random.normal(key, acts.shape, jnp.float32)
    axes = tuple(range(1, acts.ndim))
    g_norm = jnp.sqrt(jnp.sum(jnp.square(acts.astype(jnp.float32)), axis=axes, keepdims=True))
    n_norm = jnp.sqrt(jnp.sum(jnp.square(n), axis=axes, keepdims=True))
    n_scaled = n * (g_norm / jnp.maximum(n_norm, 1e-12))
    out = keep * acts.astype(jnp.float32) + (1.0 - keep) * n_scaled
    return out.astype(acts.dtype)


def tamper_activation(attack: Attack, acts: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    if attack.kind != ACTIVATION:
        return acts
    return _noise_blend(acts, key, attack.act_keep)


def tamper_gradient(attack: Attack, g: jnp.ndarray) -> jnp.ndarray:
    if attack.kind != GRADIENT:
        return g
    return -g


# ---------------------------------------------------------------------------
# vmappable attack state
# ---------------------------------------------------------------------------
#
# ``Attack`` is static (one compiled program per kind).  The batched engine
# instead runs every (cluster, client) slot through ONE program, so the attack
# configuration must be *data*: ``AttackVec`` is a pytree of arrays whose
# leaves carry arbitrary leading batch axes — (M_bar,) per cluster, (R, M_bar)
# per round, (S, R, M_bar) per seed sweep — and the transforms below select
# between the honest and tampered message with ``jnp.where`` so honest slots
# reproduce the un-attacked values exactly (bit-for-bit).

class AttackVec(NamedTuple):
    flip: jnp.ndarray        # bool   — label flipping active
    shift: jnp.ndarray       # int32  — label shift amount
    act: jnp.ndarray         # bool   — activation tampering active
    act_keep: jnp.ndarray    # float32 — fraction of the true activation kept
    grad: jnp.ndarray        # bool   — gradient (sign-reversal) tampering active


def attack_vec(attack: Attack, active) -> AttackVec:
    """Per-client attack state.  ``active`` may be a bool or a bool array;
    param-tampering clients train honestly (Section III-C), so only the three
    message-level attacks ever raise a flag here."""
    on = np.asarray(active, bool)
    return AttackVec(
        flip=jnp.asarray(on & (attack.kind == LABEL_FLIP)),
        shift=jnp.broadcast_to(jnp.int32(attack.label_shift), on.shape)
        if on.shape else jnp.int32(attack.label_shift),
        act=jnp.asarray(on & (attack.kind == ACTIVATION)),
        act_keep=jnp.broadcast_to(jnp.float32(attack.act_keep), on.shape)
        if on.shape else jnp.float32(attack.act_keep),
        grad=jnp.asarray(on & (attack.kind == GRADIENT)),
    )


def attack_vec_for_clusters(attack: Attack, clusters: Sequence[Sequence[int]],
                            malicious: Set[int]) -> AttackVec:
    """(R, M_bar)-leaved AttackVec for one round's cluster partition."""
    active = np.array([[c in malicious for c in cluster] for cluster in clusters])
    return attack_vec(attack, active)


def flip_labels_vec(av: AttackVec, y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    return jnp.where(av.flip, (y + av.shift) % n_classes, y)


def tamper_activation_vec(av: AttackVec, acts: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    out = _noise_blend(acts, key, av.act_keep.astype(jnp.float32))
    return jnp.where(av.act, out, acts)


def tamper_gradient_vec(av: AttackVec, g: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(av.grad, -g, g)


def tamper_params(attack: Attack, params, key: jax.Array):
    """Section III-C: the malicious *last* client of the selected cluster
    hands off manipulated client-side parameters to the next round."""
    if attack.kind != PARAM_TAMPER:
        return params
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    tampered = [l + attack.param_scale * jax.random.normal(k, l.shape, l.dtype)
                for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, tampered)
