"""The three attack models of Section II (and the Section III-C parameter
tampering used against the validation mechanism).

All three are implemented exactly as parameterised in Section V-A:

  * label flipping       y -> (y + 3) mod n_classes
  * activation tampering g -> 0.1 * g + 0.9 * n~,  n~ = (|g|/|n|) n,
                          n ~ N(0, I)  (norm-matched noise)
  * gradient tampering   grad_c -> -grad_c  (sign reversal)

``Attack`` is a frozen (hashable) dataclass so it can be a static jit arg —
each attack kind compiles its own specialised update step, mirroring the fact
that honest and malicious clients run different computations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

NONE = "none"
LABEL_FLIP = "label_flip"
ACTIVATION = "activation"
GRADIENT = "gradient"
PARAM_TAMPER = "param_tamper"       # Section III-C: tampering the handed-off params

KINDS = (NONE, LABEL_FLIP, ACTIVATION, GRADIENT, PARAM_TAMPER)


@dataclasses.dataclass(frozen=True)
class Attack:
    kind: str = NONE
    label_shift: int = 3
    act_keep: float = 0.1            # fraction of the true activation kept
    param_scale: float = 5.0         # multiplier used by the param-tamper attack

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


HONEST = Attack(NONE)


def flip_labels(attack: Attack, y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    if attack.kind != LABEL_FLIP:
        return y
    return (y + attack.label_shift) % n_classes


def tamper_activation(attack: Attack, acts: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    if attack.kind != ACTIVATION:
        return acts
    n = jax.random.normal(key, acts.shape, jnp.float32)
    # norm-match per sample (leading axis = batch)
    axes = tuple(range(1, acts.ndim))
    g_norm = jnp.sqrt(jnp.sum(jnp.square(acts.astype(jnp.float32)), axis=axes, keepdims=True))
    n_norm = jnp.sqrt(jnp.sum(jnp.square(n), axis=axes, keepdims=True))
    n_scaled = n * (g_norm / jnp.maximum(n_norm, 1e-12))
    out = attack.act_keep * acts.astype(jnp.float32) + (1.0 - attack.act_keep) * n_scaled
    return out.astype(acts.dtype)


def tamper_gradient(attack: Attack, g: jnp.ndarray) -> jnp.ndarray:
    if attack.kind != GRADIENT:
        return g
    return -g


def tamper_params(attack: Attack, params, key: jax.Array):
    """Section III-C: the malicious *last* client of the selected cluster
    hands off manipulated client-side parameters to the next round."""
    if attack.kind != PARAM_TAMPER:
        return params
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    tampered = [l + attack.param_scale * jax.random.normal(k, l.shape, l.dtype)
                for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, tampered)
