"""JAX persistent compilation cache wiring.

Round-block execution trades many small XLA programs for a few large
scanned ones; the large programs are expensive to compile but perfectly
reusable across processes (benchmark grids, CI legs, resumed runs re-trace
byte-identical HLO).  This module turns on JAX's on-disk compilation cache
and exposes hit/miss counters so :func:`repro.telemetry.metrics.jit_cache_stats`
can surface whether a run actually paid for its compiles or loaded them.

``enable_compile_cache(path)`` is idempotent and safe to call before any
program is traced.  The thresholds are pinned to "cache everything"
(``min_compile_time_secs=0``, ``min_entry_size_bytes=-1``) because the
protocol layer compiles a small, known set of round programs — there is no
long tail of tiny throwaway executables to pollute the cache with.

The counters come from ``jax.monitoring`` events
(``/jax/compilation_cache/cache_hits`` / ``…/cache_misses``); they count
*this process's* lookups, so a warm cache shows hits only after
``jax.clear_caches()`` or in a fresh process.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Dict, Optional

ENV_VAR = "REPRO_COMPILE_CACHE"

_lock = threading.Lock()
_state: Dict[str, Any] = {"dir": None, "hits": 0, "misses": 0,
                          "listener": False}


def _on_event(event: str, **kwargs) -> None:  # pragma: no cover - thin shim
    if event == "/jax/compilation_cache/cache_hits":
        _state["hits"] += 1
    elif event == "/jax/compilation_cache/cache_misses":
        _state["misses"] += 1


def enable_compile_cache(path: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at ``path`` and start
    counting hits/misses.

    ``path=None`` falls back to the ``REPRO_COMPILE_CACHE`` environment
    variable; if that is unset too, this is a no-op returning ``None`` (the
    cache stays off).  Returns the directory in use otherwise.  Idempotent:
    repeated calls re-point the directory but register the event listener
    only once."""
    d = path if path is not None else os.environ.get(ENV_VAR)
    if not d:
        return None
    import jax

    os.makedirs(d, exist_ok=True)
    with _lock:
        jax.config.update("jax_compilation_cache_dir", d)
        # cache every executable regardless of compile time / size: the
        # protocol layer only builds a handful of round programs
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        try:
            # the backend memoises "no cache" at its first compile; if any
            # program was compiled before this call, force a re-read of the
            # (now set) cache dir
            from jax.experimental.compilation_cache import \
                compilation_cache as _jcc
            _jcc.reset_cache()
        except Exception:  # pragma: no cover - private-API drift tolerance
            pass
        if not _state["listener"]:
            jax.monitoring.register_event_listener(_on_event)
            _state["listener"] = True
        _state["dir"] = d
    return d


def compile_cache_stats() -> Dict[str, Any]:
    """Snapshot of the persistent-cache state for telemetry: the directory
    (``None`` = disabled), the number of cache files on disk, and this
    process's lookup hit/miss counters."""
    d = _state["dir"]
    entries = 0
    if d is not None and os.path.isdir(d):
        entries = sum(1 for n in os.listdir(d)
                      if os.path.isfile(os.path.join(d, n)))
    return {"persistent_cache_dir": d,
            "persistent_cache_entries": entries,
            "persistent_cache_hits": _state["hits"],
            "persistent_cache_misses": _state["misses"]}
