"""Protocol drivers: Pigeon-SL (Algorithm 1), Pigeon-SL+, vanilla SL and the
clustered SplitFed baseline of Section V.

Every driver returns a ``History`` whose per-round records include test
accuracy, per-cluster validation losses, the selected cluster, whether that
cluster was honest, tamper-detection events, and message-count accounting
(floats transmitted, client fwd+bwd passes) so that Table I's complexity
formulas can be validated against the measured counts.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..adversary import ThreatModel, resolve_threat_model
from ..selection import resolve_policy, select_host
from ..telemetry import NULL_SESSION, Telemetry, resolve_telemetry
from .attacks import Attack, HONEST
from .clustering import cluster_is_honest, make_clusters
from .comm import CommConfig, FLOAT_BYTES, message_bytes
from .split import SplitModule, client_update, client_update_stats
from .validation import validation_loss

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ProtocolConfig:
    M: int                    # total clients
    N: int = 0                # tolerated malicious clients; R = N + 1
    T: int = 50               # global rounds
    E: int = 10               # mini-batch updates per client turn
    B: int = 64               # mini-batch size
    lr: float = 1e-3
    seed: int = 0
    tamper_check: bool = True
    tamper_tol: float = 1e-4
    eval_every: int = 1
    eval_batch: int = 500
    comm: CommConfig = CommConfig()
    # Observability config (spans / sinks / profiler — see repro.telemetry);
    # None = off.  A driver-level ``telemetry=`` kwarg takes precedence.
    telemetry: Optional[Telemetry] = None

    @property
    def R(self) -> int:
        return self.N + 1

    @property
    def quant(self) -> Optional[str]:
        """Cut-layer wire format (``None`` = f32) — see :mod:`core.comm`."""
        return self.comm.quant


@dataclasses.dataclass
class ClientData:
    """Per-client local shards + the shared/reference and test sets."""
    x: np.ndarray             # (M, D_m, ...)
    y: np.ndarray             # (M, D_m)
    x0: np.ndarray            # (D_o, ...) shared validation inputs
    y0: np.ndarray            # (D_o,)
    x_test: np.ndarray
    y_test: np.ndarray


@dataclasses.dataclass
class CommMeter:
    """Message accounting in float-counts (Table I units: d_c, d_CL) and in
    wire bytes.  Float counts are format-independent — they count message
    *elements*, so Table I's formulas stay valid under any ``CommConfig``;
    the ``*_bytes`` fields measure the actual wire (quantized cut-layer
    exchanges charge ``itemsize*elements + 4 bytes/row``; defense-critical
    validation pushes and parameter handoffs always travel f32)."""
    activation_floats: int = 0      # cut-layer activations, both directions
    gradient_floats: int = 0        # cut-layer gradients
    param_floats: int = 0           # client-side parameter handoffs (d_CL)
    validation_floats: int = 0      # shared-set activations for validation/check
    client_passes: int = 0          # forward(+backward) passes through gamma (F_CL)
    activation_bytes: int = 0       # wire bytes of the uplink cut activations
    gradient_bytes: int = 0         # wire bytes of the downlink cut gradients
    param_bytes: int = 0            # wire bytes of parameter handoffs (f32)
    validation_bytes: int = 0       # wire bytes of validation pushes (f32)

    def total_comm(self) -> int:
        return (self.activation_floats + self.gradient_floats
                + self.param_floats + self.validation_floats)

    def total_bytes(self) -> int:
        return (self.activation_bytes + self.gradient_bytes
                + self.param_bytes + self.validation_bytes)

    def exchange_bytes(self) -> int:
        """Wire bytes of the two quantizable cut-layer message streams."""
        return self.activation_bytes + self.gradient_bytes


@dataclasses.dataclass
class History:
    rounds: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def series(self, key):
        return [r.get(key) for r in self.rounds]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _count_params(tree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def sample_batch_idx(rng: np.random.Generator, n: int, e: int, b: int) -> np.ndarray:
    """(E, B) mini-batch indices for one client turn.  The single batch-
    sampling primitive shared by both engines: the sequential/batched
    equivalence contract requires them to consume the numpy RNG identically,
    so any change to the sampling scheme must go through here."""
    return rng.integers(0, n, size=(e, b))


def _sample_batches(rng: np.random.Generator, x: np.ndarray, y: np.ndarray,
                    e: int, b: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    idx = sample_batch_idx(rng, x.shape[0], e, b)
    return jnp.asarray(x[idx]), jnp.asarray(y[idx])


ENGINES = ("sequential", "batched")


def _check_engine(engine: str, placement: str = "vmap",
                  prefetch: int = 0) -> None:
    if engine not in ENGINES:
        raise ValueError(f"engine={engine!r} must be one of {ENGINES}")
    from .runner import check_placement
    check_placement(placement)
    if placement != "vmap" and engine != "batched":
        raise ValueError(f"placement={placement!r} requires engine='batched' "
                         f"(the sequential oracle has no cluster axis to place)")
    if prefetch > 0 and engine != "batched":
        raise ValueError(f"prefetch={prefetch} requires engine='batched' "
                         f"(the sequential oracle assembles per client turn)")


def check_block(block: int, engine: str = "batched", *, plus: bool = False,
                has_param_tamper: bool = False,
                force_host_selection: bool = False, eval_every: int = 1,
                checkpoint_path: Optional[str] = None,
                checkpoint_every: int = 1) -> int:
    """Validate the round-block knobs up front (mirroring
    :func:`_check_engine`) and return the *effective* block size.

    Impossible combinations raise; the forced-per-round cases — Pigeon-SL+
    sub-round sampling and param-tamper handoff key splits, where the data
    for round t+1 depends on round t's selection — warn and degrade to
    ``block=1`` so callers can thread ``block=`` unconditionally, exactly as
    ``prefetch`` degrades to synchronous assembly at the same phase
    boundaries.  Sync-cadence degradations (``eval_every=1`` /
    ``checkpoint_every=1`` make every round a host sync point, so blocks
    shrink back to single rounds) keep the requested block but warn, since
    they silently erase the fusion win."""
    import warnings
    if block < 1:
        raise ValueError(f"block={block} must be >= 1")
    if checkpoint_every < 1:
        raise ValueError(f"checkpoint_every={checkpoint_every} must be >= 1")
    if block == 1:
        return 1
    if engine != "batched":
        raise ValueError(
            f"block={block} requires engine='batched' (the sequential "
            f"oracle dispatches per client turn and cannot scan rounds)")
    if plus:
        warnings.warn(
            f"block={block} forced to 1: Pigeon-SL+ sub-rounds sample the "
            f"previous round's selected cluster, so round t+1's host "
            f"assembly cannot run before round t's selection", stacklevel=3)
        return 1
    if has_param_tamper:
        warnings.warn(
            f"block={block} forced to 1: param-tamper threat models split "
            f"the protocol key per visited candidate during host-side "
            f"selection, which is inherently per-round", stacklevel=3)
        return 1
    if force_host_selection:
        warnings.warn(
            f"block={block} forced to 1: the host-side reference cascade "
            f"needs every round's candidates on the host", stacklevel=3)
        return 1
    if eval_every == 1:
        warnings.warn(
            f"block={block} degrades to per-round execution: eval_every=1 "
            f"makes every round an eval sync point — raise pcfg.eval_every "
            f"to let rounds fuse", stacklevel=3)
    elif checkpoint_path is not None and checkpoint_every == 1:
        warnings.warn(
            f"block={block} degrades to per-round execution: "
            f"checkpoint_every=1 checkpoints every round — raise "
            f"checkpoint_every to let rounds fuse", stacklevel=3)
    return block


def account_client_turn(meter: CommMeter, pcfg: ProtocolConfig, d_c: int,
                        d_cl: int, handoff: bool) -> None:
    """Table I accounting for one client's turn (E batches of B samples:
    activations up, cut gradients down, plus the intra-cluster parameter
    handoff).  Shared by the sequential and batched engines so their
    CommMeter counts are bit-identical by construction.  Byte charges read
    ``pcfg.comm.quant``: each of the E batches is one (B, d_c) quantized
    message per direction (1 byte/element + one f32 scale per sample);
    handoffs stay f32."""
    quant = pcfg.comm.quant
    n_samples = pcfg.E * pcfg.B
    meter.client_passes += n_samples
    meter.activation_floats += n_samples * d_c
    meter.gradient_floats += n_samples * d_c
    meter.activation_bytes += pcfg.E * message_bytes(quant, pcfg.B, d_c)
    meter.gradient_bytes += pcfg.E * message_bytes(quant, pcfg.B, d_c)
    if handoff:
        account_param_transfer(meter, d_cl)


def account_validation(meter: CommMeter, d_o: int, d_c: int) -> None:
    """One cluster's shared-set validation push (Section III-C) — always f32:
    quantizing the message the tamper check and selection scores read would
    let an attacker hide inside quantization noise."""
    meter.validation_floats += d_o * d_c
    meter.validation_bytes += d_o * d_c * FLOAT_BYTES
    meter.client_passes += d_o


def account_param_transfer(meter: CommMeter, n_floats: int) -> None:
    """A parameter transfer of ``n_floats`` f32 values (handoffs, broadcasts,
    FedAvg uploads) — the single site that keeps ``param_floats`` and
    ``param_bytes`` consistent."""
    meter.param_floats += n_floats
    meter.param_bytes += n_floats * FLOAT_BYTES


def account_handoff_recheck(meter: CommMeter, pcfg: ProtocolConfig, d_o: int,
                            d_c: int, visited: int = 1) -> None:
    """Tamper-check replay of the R-candidate handoff chain for ``visited``
    inspected candidates (shared-set push per cluster, f32)."""
    meter.validation_floats += visited * pcfg.R * d_o * d_c
    meter.validation_bytes += visited * pcfg.R * d_o * d_c * FLOAT_BYTES
    meter.client_passes += visited * pcfg.R * d_o


def account_splitfed_round(meter: CommMeter, pcfg: ProtocolConfig, clusters,
                           d_o: int, d_c: int, d_cl: int) -> None:
    """One SplitFed round's message accounting — analytic, so it is
    engine-independent (bit-identical across sequential/batched/fused by
    construction): every client runs its E x B exchanges in parallel from the
    same incoming params and uploads its client-side params for the FedAvg
    combine (``handoff=True``); each cluster pushes one shared-set
    validation; the selected cluster's client params broadcast to all M
    clients for the next round."""
    for cluster in clusters:
        for _ in cluster:
            account_client_turn(meter, pcfg, d_c, d_cl, handoff=True)
        account_validation(meter, d_o, d_c)
    n_clients = sum(len(c) for c in clusters)
    account_param_transfer(meter, n_clients * d_cl)


def res_params(res: Dict[str, Any]) -> Tuple[Pytree, Pytree]:
    """(gamma, phi) of one cluster result.  The batched engine returns its R
    candidates as views into stacked arrays and only the clusters the
    selection loop actually inspects (usually one) get sliced out — R x
    n_leaves tiny slice dispatches per round would otherwise erase much of
    the batching win."""
    if "gamma" not in res:
        gs, ps, _, r = res["_stacked"]
        res["gamma"] = jax.tree.map(lambda a: a[r], gs)
        res["phi"] = jax.tree.map(lambda a: a[r], ps)
    return res["gamma"], res["phi"]


def res_vacts(res: Dict[str, Any]):
    """The cluster's validation-time cut activations (for the handoff check)."""
    if "vacts" not in res:
        _, _, vacts, r = res["_stacked"]
        res["vacts"] = vacts[r]
    return res["vacts"]


@lru_cache(maxsize=None)
def _eval_count_fn(module: SplitModule):
    """Jitted predict-and-count-correct reduction: each eval batch is one
    device op returning a single int32, instead of a full logits transfer
    followed by a host argmax.  Covers both the classifier (B, C) and LM
    (B, S, V) logit layouts — argmax over the trailing class axis, summed
    over every remaining label position."""

    @jax.jit
    def count(gamma, phi, xb, yb):
        logits = module.predict(gamma, phi, xb)
        return jnp.sum(jnp.argmax(logits, axis=-1) == yb, dtype=jnp.int32)

    return count


def evaluate(module: SplitModule, gamma, phi, x_test: np.ndarray, y_test: np.ndarray,
             batch: int = 500) -> float:
    if x_test.shape[0] == 0:
        return 0.0      # empty test set: zero correct out of zero, not a crash
    count = _eval_count_fn(module)
    correct = None
    total = 0
    for i in range(0, x_test.shape[0], batch):
        xb = jnp.asarray(x_test[i : i + batch])
        yb = jnp.asarray(y_test[i : i + batch])
        c = count(gamma, phi, xb, yb)
        correct = c if correct is None else correct + c   # stays on device
        total += int(np.prod(y_test[i : i + batch].shape))
    return float(correct) / float(total)                  # one final sync


# ---------------------------------------------------------------------------
# cluster-wise vanilla-SL training pass (lines 3-20 of Algorithm 1)
# ---------------------------------------------------------------------------

def train_cluster(module: SplitModule, gamma, phi, cluster: Sequence[int],
                  data: ClientData, pcfg: ProtocolConfig, tm: ThreatModel,
                  t: int, rng: np.random.Generator, key: jax.Array,
                  meter: CommMeter, d_c: int, collect_stats: bool = False):
    """One cluster's within-cluster client chain.  With ``collect_stats``
    additionally returns the (M_bar, S) per-client transmitted-message
    statistics (``core.split.message_stats``) the anomaly-scoring selection
    policies read; the parameter/loss arithmetic is identical either way."""
    d_cl = _count_params(gamma)
    losses = []
    stats = []
    for j, client in enumerate(cluster):
        xs, ys = _sample_batches(rng, data.x[client], data.y[client], pcfg.E, pcfg.B)
        key, sub = jax.random.split(key)
        a = tm.attack_for(client, t)
        if collect_stats:
            gamma, phi, loss, st = client_update_stats(module, a, gamma, phi,
                                                       (xs, ys), pcfg.lr, sub,
                                                       quant=pcfg.comm.quant)
            stats.append(np.asarray(st))
        else:
            gamma, phi, loss = client_update(module, a, gamma, phi, (xs, ys),
                                             pcfg.lr, sub,
                                             quant=pcfg.comm.quant)
        losses.append(float(loss))
        account_client_turn(meter, pcfg, d_c, d_cl, handoff=j < len(cluster) - 1)
    if collect_stats:
        return gamma, phi, float(np.mean(losses)), np.stack(stats)
    return gamma, phi, float(np.mean(losses))


def cut_width(module: SplitModule, gamma, x0) -> int:
    """d_c: per-sample width of the cut-layer activation message (computed
    shape-only via eval_shape — no allocation)."""
    shp = jax.eval_shape(module.client_forward, gamma, jnp.asarray(x0[:1]))
    return int(np.prod(shp.shape[1:]))


# ---------------------------------------------------------------------------
# Pigeon-SL / Pigeon-SL+
# ---------------------------------------------------------------------------

def _train_round(module: SplitModule, theta, clusters, data: ClientData,
                 pcfg: ProtocolConfig, tm: ThreatModel, t: int,
                 rng: np.random.Generator, key: jax.Array, meter: CommMeter,
                 d_c: int, x0, y0, engine: str, placement: str = "vmap",
                 prefetched=None, with_stats: bool = False, telemetry=None):
    """Train all R clusters of round t from the same theta^t.  Returns
    (key', results) where results[r] holds gamma/phi/vloss/vacts/cluster/
    train_loss for cluster r.  Both engines consume the numpy RNG and the JAX
    key stream in the same order, so they are swappable mid-trajectory."""
    tel = NULL_SESSION if telemetry is None else telemetry
    if engine == "batched":
        from .engine import train_round_batched
        return train_round_batched(module, theta, clusters, data, pcfg,
                                   tm, t, rng, key, meter, d_c, x0, y0,
                                   placement=placement, prefetched=prefetched,
                                   with_stats=with_stats, telemetry=tel)
    results = []
    with tel.span("round.step", round=t):
        for cluster in clusters:
            key, sub = jax.random.split(key)
            out = train_cluster(module, theta[0], theta[1], cluster, data,
                                pcfg, tm, t, rng, sub, meter, d_c,
                                collect_stats=with_stats)
            g, p, train_loss = out[:3]
            vloss, vacts = validation_loss(module, g, p, x0, y0)
            res = dict(gamma=g, phi=p, vloss=float(vloss), vacts=vacts,
                       cluster=cluster, train_loss=train_loss)
            if with_stats:
                res["msg_stats"] = out[3]
            results.append(res)
    return key, results


def run_pigeon(module: SplitModule, data: ClientData, pcfg: ProtocolConfig,
               malicious: Optional[Set[int]] = None, attack: Attack = HONEST,
               plus: bool = False, verbose: bool = False,
               checkpoint_path: Optional[str] = None, resume: bool = False,
               engine: str = "sequential", placement: str = "vmap",
               prefetch: int = 0, block: int = 1, checkpoint_every: int = 1,
               threat_model: Optional[ThreatModel] = None,
               selection="argmin", quant: Optional[str] = None,
               telemetry=None,
               _force_host_selection: bool = False) -> History:
    """Pigeon-SL (Algorithm 1).  Execution knobs beyond the paper:

    * ``telemetry`` — a :class:`repro.telemetry.Telemetry` config (or an
      already-open session, which the driver borrows without closing):
      phase spans, per-round metric events, JSONL/console/custom sinks and
      opt-in profiler windows.  Overrides ``pcfg.telemetry``.  Telemetry is
      a strict no-op on the math — it consumes no RNG and adds no
      device→host fetches — so the History is bit-identical with it on or
      off.  ``verbose=True`` is a back-compat alias for the console sink
      (one uniform per-round line).

    * ``quant`` — cut-layer wire format shorthand (``"int8"`` /
      ``"fp8_e4m3"``; ``None`` keeps ``pcfg.comm``): overrides the
      ``ProtocolConfig.comm`` transport config for this run.  See
      :mod:`repro.core.comm` for what is (and is not) quantized.

    * ``engine`` — ``"sequential"`` (reference oracle) or ``"batched"`` (one
      compiled program per round via the RoundRunner).  For MANY concurrent
      runs of compatible specs, :func:`repro.core.jobs.run_job_pool`
      megabatches them onto a shared job-lane program (one dispatch and one
      stacked fetch per pool block across all jobs) with each job's History
      bit-identical to its solo ``run_pigeon`` — this driver stays the
      single-job reference path the pool is pinned against.
    * ``selection`` — a registered :mod:`repro.selection` policy name
      (``"argmin"`` / ``"median_of_means"`` / ``"loss_plus_distance"`` /
      ``"trimmed"``) or a policy instance.  The default ``"argmin"`` is the
      paper's rule and reproduces the pre-subsystem trajectories
      bit-for-bit.  Under the batched engine the whole acceptance cascade
      (score -> rank -> handoff verify -> commit) is compiled into the round
      program with a single stacked host fetch per round; the host-side
      reference cascade (``repro.selection.select_host``) runs for the
      sequential oracle and for param-tamper threat models, whose handoff
      tampering consumes the protocol key per visited candidate.
      ``_force_host_selection`` pins the batched engine to the host cascade
      (the equivalence suite's oracle knob).
    * ``placement`` — batched engine only: ``"vmap"`` (cluster axis vmapped
      on one device) or ``"sharded"`` (cluster axis laid over a device mesh).
    * ``prefetch`` — batched engine only: double-buffer host-side round
      assembly (batch gathering, key derivation, device transfer) ``prefetch``
      rounds ahead on a background thread (``data/pipeline.py::RoundFeeder``).
      The RNG/key consumption order is preserved exactly, so the trajectory
      is bit-identical to ``prefetch=0``.  The feeder bounds its depth to
      zero — synchronous assembly — whenever sampling depends on the previous
      round's outcome: Pigeon-SL+ sub-rounds sample the *selected* cluster,
      and param-tamper threat models consume the key stream at selection
      time, so both fall back transparently.
    * ``block`` — batched engine only: chain up to ``block`` consecutive
      rounds as ONE compiled ``lax.scan`` program with a single stacked
      ``(K, 2R+3)`` host fetch per block, from which per-round ``History``,
      telemetry round events and ``CommMeter`` deltas are replayed
      bit-identically to ``block=1``.  Host-side K-round assembly preserves
      the per-round RNG/key order exactly (``engine.assemble_block``), so
      the trajectory is unchanged.  Blocks break at *sync rounds* — eval
      rounds (``pcfg.eval_every``) and checkpoint rounds
      (``checkpoint_every``) — because intermediate thetas never leave the
      device mid-block; they are bounded to 1 (with a warning) for
      Pigeon-SL+ and param-tamper threat models, whose round t+1 data
      depends on round t's selection, exactly as ``prefetch`` falls back.
      See :func:`check_block` for the up-front validation.
    * ``checkpoint_every`` — write a checkpoint after round t only when
      ``(t+1) % checkpoint_every == 0`` (or at the final round).  The
      default 1 keeps the historical every-round cadence; raising it both
      amortises checkpoint I/O and lets round blocks fuse across the
      non-checkpointed rounds (resume restarts from the last checkpointed
      round, re-training at most ``checkpoint_every - 1`` rounds).
    * ``checkpoint_path`` / ``resume`` — per-round checkpoints carry theta
      AND the full randomness-stream state (numpy bit-generator state + the
      protocol key), so a resumed run is *on-stream*: it reproduces the
      uninterrupted trajectory bit-for-bit, under either engine, both
      placements, prefetch on or off, and Pigeon-SL+.  Checkpoint writes are
      atomic (temp file + ``os.replace``, manifest last); a torn/corrupt
      checkpoint is detected and skipped with a warning instead of being
      half-loaded.
    """
    _check_engine(engine, placement, prefetch)
    if quant is not None:
        pcfg = dataclasses.replace(pcfg, comm=CommConfig(quant=quant))
    policy = resolve_policy(selection)
    tm = resolve_threat_model(malicious, attack, threat_model)
    block = check_block(block, engine, plus=plus,
                        has_param_tamper=tm.has_param_tamper,
                        force_host_selection=_force_host_selection,
                        eval_every=pcfg.eval_every,
                        checkpoint_path=checkpoint_path,
                        checkpoint_every=checkpoint_every)
    # The fused on-device cascade covers every message-level threat model;
    # handoff (param-tamper) attacks are applied host-side and split the
    # protocol key per *visited* candidate, so they pin selection to the
    # host reference cascade (exactly like the prefetch depth bound).
    fused_selection = (engine == "batched" and not tm.has_param_tamper
                      and not _force_host_selection)
    rng = np.random.default_rng(pcfg.seed)
    key = jax.random.PRNGKey(pcfg.seed)
    key, k0 = jax.random.split(key)
    gamma0, phi0 = module.init(k0)
    theta = (gamma0, phi0)
    start_round = 0
    if resume and checkpoint_path is not None:
        from ..checkpoint import (CorruptCheckpointError, load_checkpoint,
                                  restore_protocol_state, restore_pytree)
        try:
            _, meta = load_checkpoint(checkpoint_path)
            theta = restore_pytree(checkpoint_path, theta)
            start_round = int(meta.get("round", -1)) + 1
            if "rng_state" in meta:
                # On-stream resume: restore the numpy bit-generator state and
                # the protocol key exactly as they stood after the saved
                # round, so the resumed trajectory (clustering, per-turn
                # batch sampling, per-round/tamper-check key splits) is
                # bit-identical to the uninterrupted run.
                key = restore_protocol_state(rng, key, meta)
            else:
                # Legacy checkpoints (no stream snapshot): replay only the
                # clustering draws.  Off-stream for batch sampling and key
                # splits — kept solely so old checkpoints still load.
                for _ in range(start_round):
                    make_clusters(rng, pcfg.M, pcfg.R)
        except FileNotFoundError:
            start_round = 0
        except CorruptCheckpointError as e:
            import warnings
            warnings.warn(f"ignoring corrupt checkpoint {checkpoint_path!r} "
                          f"({e}); starting from round 0", stacklevel=2)
            start_round = 0
    if start_round >= pcfg.T:
        # The checkpoint already covers the final round: training would be a
        # zero-iteration loop returning an empty History.  Surface the
        # restored state instead of silently discarding it.
        import warnings
        warnings.warn(
            f"resume: checkpoint {checkpoint_path!r} is at round "
            f"{start_round - 1} >= T-1 = {pcfg.T - 1}; nothing left to train "
            f"— returning the restored final state", stacklevel=2)
        hist = History()
        hist.rounds.append(dict(
            round=start_round - 1, resumed_terminal=True,
            test_acc=evaluate(module, theta[0], theta[1], data.x_test,
                              data.y_test, pcfg.eval_batch)))
        return hist
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)
    d_o = data.x0.shape[0]
    hist = History()
    d_cl = _count_params(gamma0)
    d_c = cut_width(module, gamma0, data.x0)
    tel = resolve_telemetry(
        telemetry if telemetry is not None else pcfg.telemetry,
        verbose=verbose, run=f"pigeon{'+' if plus else ''}",
        engine=engine, placement=placement, prefetch=prefetch, block=block,
        T=pcfg.T, M=pcfg.M, R=pcfg.R, selection=policy.name,
        fused_selection=fused_selection)

    def _ckpt_due(t: int) -> bool:
        return checkpoint_path is not None and (
            (t + 1) % checkpoint_every == 0 or t == pcfg.T - 1)

    if block > 1:
        # Round-block execution (check_block guarantees the fused batched
        # path here): K rounds chained on device as one lax.scan with the
        # selection cascade in-carry, ONE stacked host fetch per block, and
        # the per-round History / telemetry / CommMeter records replayed
        # host-side bit-identically to per-round execution.  Blocks end at
        # sync rounds (eval / checkpoint cadence) since intermediate thetas
        # never leave the device; the K-round host assembly runs through the
        # same RoundFeeder (block-indexed) so prefetch still overlaps
        # assembly of block b+1 with device execution of block b.
        from ..data.pipeline import RoundFeeder, plan_blocks
        from .engine import assemble_block, pigeon_block_accept

        def _sync_round(t: int) -> bool:
            return (t % pcfg.eval_every == 0 or t == pcfg.T - 1
                    or _ckpt_due(t))

        segments = plan_blocks(start_round, pcfg.T, block, _sync_round)

        _state = {"key": key}

        def _make_block(b):
            t0, k = segments[b]
            _state["key"], clusters_k, payload = assemble_block(
                rng, _state["key"], data, pcfg, tm, t0, k)
            # Stream snapshot for the block-end checkpoint: the fused path
            # splits no keys after assembly, so the post-block-assembly
            # stream state IS the synchronous end-of-round state of the
            # block's last round (same argument as the per-round feeder).
            snap = None
            if checkpoint_path is not None:
                from ..checkpoint import protocol_state_metadata
                snap = protocol_state_metadata(rng, _state["key"])
            return clusters_k, payload, snap

        feeder = RoundFeeder(_make_block, 0, len(segments), depth=prefetch,
                             telemetry=tel)
        try:
            for b, (t0, k) in enumerate(segments):
                tel.profile_tick(t0)
                if prefetch > 0:
                    with tel.span("round.feeder_wait", round=t0,
                                  depth=feeder.qsize()):
                        clusters_k, payload, stream_snap = feeder.get(b)
                else:
                    with tel.span("block.assemble", round=t0, k=k):
                        clusters_k, payload, stream_snap = feeder.get(b)
                theta, records = pigeon_block_accept(
                    module, theta, clusters_k, pcfg, tm, t0, payload,
                    x0, y0, policy, placement, telemetry=tel)
                for i, brec in enumerate(records):
                    t = t0 + i
                    clusters = clusters_k[i]
                    meter = CommMeter()
                    # Bit-identical replay of the per-round accounting:
                    # client turns + tamper re-checks (pigeon_round_accept's
                    # internal charges) followed by the driver's validation
                    # pushes and the winner broadcast.
                    for cluster in clusters:
                        for j in range(len(cluster)):
                            account_client_turn(meter, pcfg, d_c, d_cl,
                                                handoff=j < len(cluster) - 1)
                    if pcfg.tamper_check:
                        visited = brec["detections"] + (1 if brec["accepted"]
                                                        else 0)
                        account_handoff_recheck(meter, pcfg, d_o, d_c,
                                                visited)
                    for _ in clusters:
                        account_validation(meter, d_o, d_c)
                    if brec["accepted"]:
                        account_param_transfer(meter, pcfg.R * d_cl)
                    sel_cluster = clusters[brec["selected"]]
                    rec = dict(
                        round=t,
                        clusters=clusters,
                        val_losses=brec["val_losses"],
                        train_losses=brec["train_losses"],
                        selected=brec["selected"],
                        accepted=brec["accepted"],
                        selected_honest=cluster_is_honest(sel_cluster,
                                                          tm.malicious),
                        honest_cluster_exists=any(
                            cluster_is_honest(c, tm.malicious)
                            for c in clusters),
                        detections=brec["detections"],
                        comm=dataclasses.asdict(meter),
                    )
                    if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                        # only reachable at the block's last scanned round:
                        # plan_blocks breaks blocks at eval sync rounds, so
                        # theta is exactly the post-round-t state
                        with tel.span("round.eval", round=t):
                            rec["test_acc"] = evaluate(
                                module, theta[0], theta[1], data.x_test,
                                data.y_test, pcfg.eval_batch)
                    hist.rounds.append(rec)
                    if _ckpt_due(t):
                        from ..checkpoint import save_checkpoint
                        with tel.span("round.checkpoint", round=t):
                            save_checkpoint(checkpoint_path, theta,
                                            {"round": t, **stream_snap})
                    tel.record_round(t, rec,
                                     feeder_depth=(feeder.qsize()
                                                   if prefetch > 0 else None))
        finally:
            feeder.close()
            tel.close()
        return hist

    # Double-buffered host pipeline: assembly of round t+1 overlaps device
    # execution of round t.  Depth is bounded to zero (synchronous) at the
    # phase boundaries where sampling depends on round t's outcome — the
    # Pigeon-SL+ sub-rounds resample the selected cluster, and param-tamper
    # threat models split the protocol key during selection.
    feeder = None
    if engine == "batched" and prefetch > 0 and not plus \
            and not tm.has_param_tamper:
        from ..data.pipeline import RoundFeeder
        from .engine import assemble_round

        _state = {"key": key}

        def _make_round(t):
            clusters = make_clusters(rng, pcfg.M, pcfg.R)
            _state["key"], payload = assemble_round(
                rng, _state["key"], data, clusters, pcfg, tm, t)
            # Stream snapshot for the round-t checkpoint: by the time the
            # main loop saves round t, the feeder has already consumed the
            # RNG/key streams for rounds t+1.., so the snapshot must be taken
            # here — right after round t's assembly, which (feeder
            # preconditions: no Pigeon-SL+ sub-rounds, no param-tamper key
            # splits) is exactly the synchronous end-of-round-t state.
            snap = None
            if checkpoint_path is not None:
                from ..checkpoint import protocol_state_metadata
                snap = protocol_state_metadata(rng, _state["key"])
            return clusters, payload, snap

        feeder = RoundFeeder(_make_round, start_round, pcfg.T, depth=prefetch,
                             telemetry=tel)

    try:
        for t in range(start_round, pcfg.T):
            tel.profile_tick(t)
            meter = CommMeter()
            if feeder is not None:
                with tel.span("round.feeder_wait", round=t,
                              depth=feeder.qsize()):
                    clusters, prefetched, stream_snap = feeder.get(t)
            else:
                clusters = make_clusters(rng, pcfg.M, pcfg.R)
                prefetched = None
                stream_snap = None
            if fused_selection:
                # Default batched path: train + validate + the whole
                # score/rank/verify/commit cascade in ONE compiled program;
                # the stacked record fetch is the round's single host sync.
                from .engine import pigeon_round_accept
                key, theta, sel_rec = pigeon_round_accept(
                    module, theta, clusters, data, pcfg, tm, t, rng, key,
                    meter, d_c, x0, y0, policy, placement, prefetched,
                    telemetry=tel)
                selected = sel_rec["selected"]
                accepted = sel_rec["accepted"]
                detection_events = sel_rec["detections"]
                val_losses = sel_rec["val_losses"]
                train_losses = sel_rec["train_losses"]
                sel_cluster = clusters[selected]
            else:
                # Reference path (sequential oracle / param-tamper threat
                # models): all R candidates, then the host-side cascade.
                key, results = _train_round(
                    module, theta, clusters, data, pcfg, tm, t, rng, key,
                    meter, d_c, x0, y0, engine, placement, prefetched,
                    with_stats=policy.needs_message_stats, telemetry=tel)
                with tel.span("round.select", round=t):
                    key, outcome = select_host(policy, module, results,
                                               theta, tm, t, key, pcfg,
                                               meter, x0, y0, d_c)
                theta = outcome.theta
                selected = outcome.selected
                accepted = outcome.accepted
                detection_events = outcome.detections
                val_losses = [res["vloss"] for res in results]
                train_losses = [res["train_loss"] for res in results]
                sel_cluster = results[selected]["cluster"]
            for _ in clusters:
                account_validation(meter, d_o, d_c)
            if accepted:
                # broadcast to next first clients (no broadcast happens when
                # every cluster failed the tamper check and theta^t is kept)
                account_param_transfer(meter, pcfg.R * d_cl)

            # Pigeon-SL+: R-1 extra sub-rounds on the selected cluster —
            # only when the round was accepted: a rejected round keeps
            # theta^t, and re-training the (tamper-flagged) selected cluster
            # from it would hand a detected attacker R-1 free extra turns.
            if plus and accepted:
                with tel.span("round.subrounds", round=t, n=pcfg.R - 1):
                    for _ in range(pcfg.R - 1):
                        if engine == "batched":
                            from .engine import train_cluster_batched
                            key, g, p, _ = train_cluster_batched(
                                module, theta, sel_cluster, data, pcfg, tm,
                                t, rng, key, meter, d_c)
                        else:
                            key, sub = jax.random.split(key)
                            g, p, _ = train_cluster(module, theta[0],
                                                    theta[1], sel_cluster,
                                                    data, pcfg, tm, t, rng,
                                                    sub, meter, d_c)
                        theta = (g, p)
                        # subround handoff to the 1st client
                        account_param_transfer(meter, _count_params(g))

            rec = dict(
                round=t,
                clusters=clusters,
                val_losses=val_losses,
                train_losses=train_losses,
                selected=selected,
                accepted=accepted,
                selected_honest=cluster_is_honest(sel_cluster, tm.malicious),
                honest_cluster_exists=any(cluster_is_honest(c, tm.malicious)
                                          for c in clusters),
                detections=detection_events,
                comm=dataclasses.asdict(meter),
            )
            if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                with tel.span("round.eval", round=t):
                    rec["test_acc"] = evaluate(module, theta[0], theta[1],
                                               data.x_test, data.y_test,
                                               pcfg.eval_batch)
            hist.rounds.append(rec)
            if _ckpt_due(t):
                from ..checkpoint import protocol_state_metadata, save_checkpoint
                state = (stream_snap if stream_snap is not None
                         else protocol_state_metadata(rng, key))
                with tel.span("round.checkpoint", round=t):
                    save_checkpoint(checkpoint_path, theta,
                                    {"round": t, **state})
            tel.record_round(t, rec,
                             feeder_depth=(feeder.qsize()
                                           if feeder is not None else None))
    finally:
        if feeder is not None:
            feeder.close()
        tel.close()
    return hist


def run_pigeon_plus(module: SplitModule, data: ClientData, pcfg: ProtocolConfig,
                    malicious: Optional[Set[int]] = None, attack: Attack = HONEST,
                    verbose: bool = False, checkpoint_path: Optional[str] = None,
                    resume: bool = False, engine: str = "sequential",
                    placement: str = "vmap", prefetch: int = 0,
                    block: int = 1, checkpoint_every: int = 1,
                    threat_model: Optional[ThreatModel] = None,
                    selection="argmin", quant: Optional[str] = None,
                    telemetry=None) -> History:
    """Pigeon-SL+ (throughput-matched variant): ``run_pigeon`` with the R-1
    extra selected-cluster sub-rounds enabled.  ``prefetch`` and ``block``
    are accepted for API symmetry but bounded to synchronous per-round
    execution — the sub-rounds sample the selected cluster, so round t+1's
    host work cannot start (and no round may chain on device) before round
    t's selection."""
    return run_pigeon(module, data, pcfg, malicious, attack, plus=True,
                      verbose=verbose, checkpoint_path=checkpoint_path,
                      resume=resume, engine=engine, placement=placement,
                      prefetch=prefetch, block=block,
                      checkpoint_every=checkpoint_every,
                      threat_model=threat_model,
                      selection=selection, quant=quant, telemetry=telemetry)


# ---------------------------------------------------------------------------
# vanilla SL (the paper's baseline)
# ---------------------------------------------------------------------------

def run_vanilla_sl(module: SplitModule, data: ClientData, pcfg: ProtocolConfig,
                   malicious: Optional[Set[int]] = None, attack: Attack = HONEST,
                   verbose: bool = False,
                   threat_model: Optional[ThreatModel] = None,
                   quant: Optional[str] = None, telemetry=None) -> History:
    if quant is not None:
        pcfg = dataclasses.replace(pcfg, comm=CommConfig(quant=quant))
    tm = resolve_threat_model(malicious, attack, threat_model)
    rng = np.random.default_rng(pcfg.seed)
    key = jax.random.PRNGKey(pcfg.seed)
    key, k0 = jax.random.split(key)
    gamma, phi = module.init(k0)
    hist = History()
    d_c = cut_width(module, gamma, data.x0)
    tel = resolve_telemetry(
        telemetry if telemetry is not None else pcfg.telemetry,
        verbose=verbose, run="vanilla", T=pcfg.T, M=pcfg.M)
    try:
        for t in range(pcfg.T):
            tel.profile_tick(t)
            meter = CommMeter()
            order = rng.permutation(pcfg.M).tolist()
            key, sub = jax.random.split(key)
            with tel.span("round.step", round=t):
                gamma, phi, train_loss = train_cluster(
                    module, gamma, phi, order, data, pcfg, tm, t, rng, sub,
                    meter, d_c)
            # hand-off into the next round
            account_param_transfer(meter, _count_params(gamma))
            rec = dict(round=t, train_loss=train_loss,
                       comm=dataclasses.asdict(meter))
            if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                with tel.span("round.eval", round=t):
                    rec["test_acc"] = evaluate(module, gamma, phi,
                                               data.x_test, data.y_test,
                                               pcfg.eval_batch)
            hist.rounds.append(rec)
            tel.record_round(t, rec)
    finally:
        tel.close()
    return hist


# ---------------------------------------------------------------------------
# SplitFed baseline (Section V: SFL + our clustering & validation selection)
# ---------------------------------------------------------------------------

def run_splitfed(module: SplitModule, data: ClientData, pcfg: ProtocolConfig,
                 malicious: Optional[Set[int]] = None, attack: Attack = HONEST,
                 verbose: bool = False, engine: str = "sequential",
                 placement: str = "vmap", prefetch: int = 0, block: int = 1,
                 threat_model: Optional[ThreatModel] = None,
                 selection="argmin", quant: Optional[str] = None,
                 telemetry=None,
                 _force_host_selection: bool = False) -> History:
    """Clients inside a cluster train *in parallel* from the same incoming
    params; the cluster model is the FedAvg of its clients.  Cluster
    selection by shared-set validation loss, as the paper's adapted SFL.

    Execution knobs match ``run_pigeon``: the batched engine runs the round
    through the placement-aware RoundRunner (SplitFed's FedAvg is the
    RoundSpec ``combine`` hook), so ``placement="sharded"`` lays the cluster
    axis over a device mesh, and ``prefetch>0`` double-buffers host-side
    round assembly.  ``selection`` plugs any :mod:`repro.selection` policy
    into the round (on the batched engine the selection cascade compiles
    into the round program — SplitFed has no chained handoff, so the verify
    stage stays off).  SplitFed sampling never depends on the previous
    round's selection — there is no tamper-check key split and no sub-round
    — so the feeder runs at full depth under every threat model, and
    ``block > 1`` chains rounds on device under every threat model too
    (blocks break only at eval sync rounds; the per-round History replayed
    from the block fetch is bit-identical to ``block=1``)."""
    _check_engine(engine, placement, prefetch)
    if quant is not None:
        pcfg = dataclasses.replace(pcfg, comm=CommConfig(quant=quant))
    policy = resolve_policy(selection)
    fused_selection = engine == "batched" and not _force_host_selection
    block = check_block(block, engine,
                        force_host_selection=_force_host_selection,
                        eval_every=pcfg.eval_every)
    tm = resolve_threat_model(malicious, attack, threat_model)
    rng = np.random.default_rng(pcfg.seed)
    key = jax.random.PRNGKey(pcfg.seed)
    key, k0 = jax.random.split(key)
    theta = module.init(k0)
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)
    hist = History()
    d_o = data.x0.shape[0]
    d_cl = _count_params(theta[0])
    d_c = cut_width(module, theta[0], data.x0)
    tel = resolve_telemetry(
        telemetry if telemetry is not None else pcfg.telemetry,
        verbose=verbose, run="sfl", engine=engine, placement=placement,
        prefetch=prefetch, block=block, T=pcfg.T, M=pcfg.M, R=pcfg.R,
        selection=policy.name, fused_selection=fused_selection)

    if block > 1:
        # Round-block execution: K FedAvg + selection-cascade rounds as one
        # scanned program, one stacked fetch per block; per-round History /
        # CommMeter replayed host-side (the SplitFed accounting is analytic,
        # so the replay is trivially bit-identical).
        from ..data.pipeline import RoundFeeder, plan_blocks
        from .engine import assemble_splitfed_block, splitfed_block_accept

        segments = plan_blocks(0, pcfg.T, block,
                               lambda t: (t % pcfg.eval_every == 0
                                          or t == pcfg.T - 1))

        _state = {"key": key}

        def _make_block(b):
            t0, k = segments[b]
            _state["key"], clusters_k, payload = assemble_splitfed_block(
                rng, _state["key"], data, pcfg, tm, t0, k)
            return clusters_k, payload

        feeder = RoundFeeder(_make_block, 0, len(segments), depth=prefetch,
                             telemetry=tel)
        try:
            for b, (t0, k) in enumerate(segments):
                tel.profile_tick(t0)
                if prefetch > 0:
                    with tel.span("round.feeder_wait", round=t0,
                                  depth=feeder.qsize()):
                        clusters_k, payload = feeder.get(b)
                else:
                    with tel.span("block.assemble", round=t0, k=k):
                        clusters_k, payload = feeder.get(b)
                theta, records = splitfed_block_accept(
                    module, theta, clusters_k, pcfg, t0, payload, x0, y0,
                    policy, placement=placement, telemetry=tel)
                for i, brec in enumerate(records):
                    t = t0 + i
                    clusters = clusters_k[i]
                    meter = CommMeter()
                    account_splitfed_round(meter, pcfg, clusters, d_o, d_c,
                                           d_cl)
                    selected = brec["selected"]
                    sel_cluster = clusters[selected]
                    rec = dict(round=t, selected=selected,
                               val_losses=brec["val_losses"],
                               selected_honest=cluster_is_honest(
                                   sel_cluster, tm.malicious),
                               comm=dataclasses.asdict(meter))
                    if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                        with tel.span("round.eval", round=t):
                            rec["test_acc"] = evaluate(
                                module, theta[0], theta[1], data.x_test,
                                data.y_test, pcfg.eval_batch)
                    hist.rounds.append(rec)
                    tel.record_round(t, rec,
                                     feeder_depth=(feeder.qsize()
                                                   if prefetch > 0 else None))
        finally:
            feeder.close()
            tel.close()
        return hist

    feeder = None
    if engine == "batched" and prefetch > 0:
        from ..data.pipeline import RoundFeeder
        from .engine import assemble_splitfed_round

        _state = {"key": key}

        def _make_round(t):
            clusters = make_clusters(rng, pcfg.M, pcfg.R)
            _state["key"], payload = assemble_splitfed_round(
                rng, _state["key"], data, clusters, pcfg, tm, t)
            return clusters, payload

        feeder = RoundFeeder(_make_round, 0, pcfg.T, depth=prefetch,
                             telemetry=tel)

    try:
        for t in range(pcfg.T):
            tel.profile_tick(t)
            meter = CommMeter()
            if feeder is not None:
                with tel.span("round.feeder_wait", round=t,
                              depth=feeder.qsize()):
                    clusters, prefetched = feeder.get(t)
            else:
                clusters = make_clusters(rng, pcfg.M, pcfg.R)
                prefetched = None
            if fused_selection:
                # Default batched path: FedAvg round + the policy selection
                # cascade in one compiled program, one stacked host fetch.
                from .engine import splitfed_round_accept
                key, theta, sel_rec = splitfed_round_accept(
                    module, theta, clusters, data, pcfg, tm, t, rng, key,
                    x0, y0, policy, placement=placement,
                    prefetched=prefetched, telemetry=tel)
                selected = sel_rec["selected"]
                val_losses = sel_rec["val_losses"]
                sel_cluster = clusters[selected]
            else:
                if engine == "batched":
                    from .engine import splitfed_round_batched
                    key, results = splitfed_round_batched(
                        module, theta, clusters, data, pcfg, tm, t, rng, key,
                        x0, y0, placement=placement, prefetched=prefetched,
                        with_stats=policy.needs_message_stats, telemetry=tel)
                else:
                    results = []
                    with tel.span("round.step", round=t):
                        for cluster in clusters:
                            gs, ps, sts = [], [], []
                            for client in cluster:
                                xs, ys = _sample_batches(rng, data.x[client],
                                                         data.y[client],
                                                         pcfg.E, pcfg.B)
                                key, sub = jax.random.split(key)
                                a = tm.attack_for(client, t)
                                if policy.needs_message_stats:
                                    g, p, _, st = client_update_stats(
                                        module, a, theta[0], theta[1],
                                        (xs, ys), pcfg.lr, sub,
                                        quant=pcfg.comm.quant)
                                    sts.append(np.asarray(st))
                                else:
                                    g, p, _ = client_update(
                                        module, a, theta[0], theta[1],
                                        (xs, ys), pcfg.lr, sub,
                                        quant=pcfg.comm.quant)
                                gs.append(g)
                                ps.append(p)
                            g_avg = jax.tree.map(
                                lambda *xs: sum(xs) / len(xs), *gs)
                            p_avg = jax.tree.map(
                                lambda *xs: sum(xs) / len(xs), *ps)
                            vloss, vacts = validation_loss(module, g_avg,
                                                           p_avg, x0, y0)
                            res = dict(gamma=g_avg, phi=p_avg, vacts=vacts,
                                       vloss=float(vloss), cluster=cluster)
                            if sts:
                                res["msg_stats"] = np.stack(sts)
                            results.append(res)
                from ..selection import host_score_context, score_and_rank
                with tel.span("round.select", round=t):
                    ctx = host_score_context(policy, module, results, x0, y0)
                    scores, elig, order = score_and_rank(policy, ctx)
                    selected = int(next(c for c in order if elig[c]))
                    theta = res_params(results[selected])
                val_losses = [res["vloss"] for res in results]
                sel_cluster = results[selected]["cluster"]
            account_splitfed_round(meter, pcfg, clusters, d_o, d_c, d_cl)
            rec = dict(round=t, selected=selected,
                       val_losses=val_losses,
                       selected_honest=cluster_is_honest(sel_cluster,
                                                         tm.malicious),
                       comm=dataclasses.asdict(meter))
            if t % pcfg.eval_every == 0 or t == pcfg.T - 1:
                with tel.span("round.eval", round=t):
                    rec["test_acc"] = evaluate(module, theta[0], theta[1],
                                               data.x_test, data.y_test,
                                               pcfg.eval_batch)
            hist.rounds.append(rec)
            tel.record_round(t, rec,
                             feeder_depth=(feeder.qsize()
                                           if feeder is not None else None))
    finally:
        if feeder is not None:
            feeder.close()
        tel.close()
    return hist
