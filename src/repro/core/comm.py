"""Cut-layer transport configuration and byte arithmetic.

The paper's Table I counts cut-layer *floats*; this module owns the wire
representation those floats travel in.  A :class:`CommConfig` on
``ProtocolConfig`` selects the quantization format of the two per-batch
cut-layer messages (activations up, cut gradients down):

  * ``quant=None``       — f32 wire, 4 bytes/element (the paper's baseline);
  * ``quant="int8"``     — per-row symmetric int8, 1 byte/element + one f32
                           scale per row;
  * ``quant="fp8_e4m3"`` — per-row-scaled fp8-e4m3 (alias ``"fp8"``), same
                           byte layout, gated on backend float8 support.

Defense-critical messages stay exact regardless of ``quant``: the shared-set
validation push (Section III-C — quantizing the message the tamper check and
selection scores read would let an attacker hide inside quantization noise)
and the intra-cluster parameter handoffs travel f32.  ``CommMeter``'s float
counts are therefore format-independent (Table I stays valid as a float
count); the ``*_bytes`` fields measure the actual wire, and the int8 win on
the exchange bytes is ``4 / (1 + 4/d_c)`` — >= 3.9x for any cut width
d_c >= 156.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..kernels.quant_exchange import QUANT_FORMATS, check_format, fp8_supported

FLOAT_BYTES = 4       # the f32 wire element
SCALE_BYTES = 4       # one f32 scale per quantized row
QUANT_ITEMSIZE = {"int8": 1, "fp8_e4m3": 1}

_ALIASES = {"fp8": "fp8_e4m3", "e4m3": "fp8_e4m3", "float8": "fp8_e4m3"}


def resolve_quant(quant: Optional[str]) -> Optional[str]:
    """Normalize a user-facing format name (``None`` passes through;
    ``"fp8"``-style aliases map to ``"fp8_e4m3"``; unknown names raise)."""
    if quant is None:
        return None
    quant = _ALIASES.get(quant, quant)
    if quant not in QUANT_FORMATS:
        raise ValueError(f"quant={quant!r} must be None or one of "
                         f"{QUANT_FORMATS} (aliases: {sorted(_ALIASES)})")
    return quant


@dataclasses.dataclass(frozen=True)
class CommConfig:
    """Cut-layer transport knobs (hashable — rides on the frozen
    ``ProtocolConfig`` and into the lru-cached runner factories)."""
    quant: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "quant", resolve_quant(self.quant))

    @property
    def itemsize(self) -> int:
        return FLOAT_BYTES if self.quant is None else QUANT_ITEMSIZE[self.quant]


def message_bytes(quant: Optional[str], n_rows: int, row_elems: int) -> int:
    """Wire bytes of one (n_rows, row_elems) cut-layer message under
    ``quant`` — the single byte formula CommMeter accounting charges."""
    if quant is None:
        return n_rows * row_elems * FLOAT_BYTES
    return n_rows * row_elems * QUANT_ITEMSIZE[quant] + n_rows * SCALE_BYTES


__all__ = ["CommConfig", "FLOAT_BYTES", "SCALE_BYTES", "QUANT_ITEMSIZE",
           "QUANT_FORMATS", "check_format", "fp8_supported", "message_bytes",
           "resolve_quant"]
