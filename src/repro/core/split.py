"""Split-learning abstraction: the gamma/phi decomposition and the vanilla-SL
mini-batch message flow (FwdProp / BackProp of Algorithms 2 & 3).

A :class:`SplitModule` is the minimal interface the Pigeon-SL protocol needs:
any model that can be cut into a client half and an AP half fits (the paper's
CNNs, and every transformer family in ``repro.models`` via ``from_lm``).

``sl_minibatch_step`` reproduces the exact four-message exchange of the
paper, with attack hooks at each of the three tampering points:

  client --- g(x, gamma), y --->  AP        (activation + label messages)
  client <---  d loss / d c  ---  AP        (cut-layer gradient message)

implemented with ``jax.vjp`` so the client-side backward consumes exactly the
(possibly tampered) cut-layer gradient the AP sent — no gradient information
bypasses the cut.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attacks import Attack, flip_labels, tamper_activation, tamper_gradient

Pytree = Any


@dataclasses.dataclass(frozen=True)
class SplitModule:
    """Pure-function view of a split model."""
    init: Callable[[jax.Array], Tuple[Pytree, Pytree]]
    client_forward: Callable[[Pytree, jnp.ndarray], jnp.ndarray]
    ap_loss: Callable[[Pytree, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    predict: Callable[[Pytree, Pytree, jnp.ndarray], jnp.ndarray]
    n_classes: int = 10

    def loss(self, gamma, phi, x, y):
        return self.ap_loss(phi, self.client_forward(gamma, x), y)


def from_cnn(cfg) -> SplitModule:
    from ..models import cnn as cnn_mod

    return SplitModule(
        init=lambda key: cnn_mod.cnn_init(key, cfg),
        client_forward=lambda g, x: cnn_mod.cnn_client_forward(g, cfg, x),
        ap_loss=lambda p, a, y: _xent(cnn_mod.cnn_ap_forward(p, cfg, a), y),
        predict=lambda g, p, x: cnn_mod.cnn_predict(g, p, cfg, x),
        n_classes=cfg.n_classes,
    )


def from_lm(model) -> SplitModule:
    """Adapt a ``repro.models.Model`` (token batches) to the SplitModule
    interface: x = tokens (B, S); y = labels (B, S)."""

    def init(key):
        params = model.init(key)
        return model.split_params(params)

    def client_forward(gamma, tokens):
        return model.client_forward(gamma, {"tokens": tokens})

    def ap_loss(phi, acts, labels):
        b = labels.shape[0]
        loss, _ = model.ap_forward(phi, acts, {"tokens": labels, "labels": labels})
        return loss

    def predict(gamma, phi, tokens):
        params = model.merge_params(gamma, phi)
        return model.logits(params, {"tokens": tokens})

    return SplitModule(init=init, client_forward=client_forward, ap_loss=ap_loss,
                       predict=predict, n_classes=model.cfg.vocab)


def _xent(logits, y):
    from ..models.blocks import cross_entropy
    return cross_entropy(logits, y)


# ---------------------------------------------------------------------------
# the SL mini-batch exchange with attack hooks
# ---------------------------------------------------------------------------

def sl_minibatch_grads(module: SplitModule, attack: Attack, gamma: Pytree, phi: Pytree,
                       x: jnp.ndarray, y: jnp.ndarray, key: jax.Array
                       ) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    """One FwdProp/BackProp exchange.  Returns (g_gamma, g_phi, loss).

    The attack hooks sit exactly where the paper places them:
      * labels tampered before transmission            (label flipping)
      * cut activations tampered before transmission   (activation tampering)
      * cut gradient tampered after reception          (gradient tampering)
    """
    y_sent = flip_labels(attack, y, module.n_classes)

    acts, client_vjp = jax.vjp(lambda g: module.client_forward(g, x), gamma)
    acts_sent = tamper_activation(attack, acts, key)

    def ap_fn(phi_, acts_):
        return module.ap_loss(phi_, acts_, y_sent)

    loss, ap_grads = jax.value_and_grad(ap_fn, argnums=(0, 1))(phi, acts_sent)
    g_phi, g_acts = ap_grads

    g_acts_recv = tamper_gradient(attack, g_acts)
    (g_gamma,) = client_vjp(g_acts_recv.astype(acts.dtype))
    return g_gamma, g_phi, loss


def sgd_update(params: Pytree, grads: Pytree, lr: float) -> Pytree:
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)


@partial(jax.jit, static_argnums=(0, 1, 5))
def client_update(module: SplitModule, attack: Attack, gamma: Pytree, phi: Pytree,
                  data: Tuple[jnp.ndarray, jnp.ndarray], lr: float, key: jax.Array
                  ) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    """E mini-batch updates for one client (lines 10-18 of Algorithm 1).

    data = (xs, ys) with xs: (E, B, ...), ys: (E, B, ...).
    """
    xs, ys = data

    def step(carry, inputs):
        gamma, phi, k = carry
        x, y = inputs
        k, sub = jax.random.split(k)
        g_gamma, g_phi, loss = sl_minibatch_grads(module, attack, gamma, phi, x, y, sub)
        return (sgd_update(gamma, g_gamma, lr), sgd_update(phi, g_phi, lr), k), loss

    (gamma, phi, _), losses = jax.lax.scan(step, (gamma, phi, key), (xs, ys))
    return gamma, phi, jnp.mean(losses)
