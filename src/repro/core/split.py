"""Split-learning abstraction: the gamma/phi decomposition and the vanilla-SL
mini-batch message flow (FwdProp / BackProp of Algorithms 2 & 3).

A :class:`SplitModule` is the minimal interface the Pigeon-SL protocol needs:
any model that can be cut into a client half and an AP half fits (the paper's
CNNs, and every transformer family in ``repro.models`` via ``from_lm``).

``sl_minibatch_step`` reproduces the exact four-message exchange of the
paper, with attack hooks at each of the three tampering points:

  client --- g(x, gamma), y --->  AP        (activation + label messages)
  client <---  d loss / d c  ---  AP        (cut-layer gradient message)

implemented with ``jax.vjp`` so the client-side backward consumes exactly the
(possibly tampered) cut-layer gradient the AP sent — no gradient information
bypasses the cut.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attacks import (Attack, AttackVec, flip_labels, flip_labels_vec,
                      poison_inputs, poison_inputs_vec, tamper_activation,
                      tamper_activation_vec, tamper_gradient,
                      tamper_gradient_vec)

Pytree = Any


@dataclasses.dataclass(frozen=True)
class SplitModule:
    """Pure-function view of a split model."""
    init: Callable[[jax.Array], Tuple[Pytree, Pytree]]
    client_forward: Callable[[Pytree, jnp.ndarray], jnp.ndarray]
    ap_loss: Callable[[Pytree, jnp.ndarray, jnp.ndarray], jnp.ndarray]
    predict: Callable[[Pytree, Pytree, jnp.ndarray], jnp.ndarray]
    n_classes: int = 10

    def loss(self, gamma, phi, x, y):
        return self.ap_loss(phi, self.client_forward(gamma, x), y)


def from_cnn(cfg) -> SplitModule:
    from ..models import cnn as cnn_mod

    return SplitModule(
        init=lambda key: cnn_mod.cnn_init(key, cfg),
        client_forward=lambda g, x: cnn_mod.cnn_client_forward(g, cfg, x),
        ap_loss=lambda p, a, y: _xent(cnn_mod.cnn_ap_forward(p, cfg, a), y),
        predict=lambda g, p, x: cnn_mod.cnn_predict(g, p, cfg, x),
        n_classes=cfg.n_classes,
    )


def from_lm(model) -> SplitModule:
    """Adapt a ``repro.models.Model`` (token batches) to the SplitModule
    interface: x = tokens (B, S); y = labels (B, S)."""

    def init(key):
        params = model.init(key)
        return model.split_params(params)

    def client_forward(gamma, tokens):
        return model.client_forward(gamma, {"tokens": tokens})

    def ap_loss(phi, acts, labels):
        b = labels.shape[0]
        loss, _ = model.ap_forward(phi, acts, {"tokens": labels, "labels": labels})
        return loss

    def predict(gamma, phi, tokens):
        params = model.merge_params(gamma, phi)
        return model.logits(params, {"tokens": tokens})

    return SplitModule(init=init, client_forward=client_forward, ap_loss=ap_loss,
                       predict=predict, n_classes=model.cfg.vocab)


def _xent(logits, y):
    from ..models.blocks import cross_entropy
    return cross_entropy(logits, y)


# ---------------------------------------------------------------------------
# AP-observable statistics of the transmitted activation message
# ---------------------------------------------------------------------------

MESSAGE_STAT_NAMES = ("dispersion", "support_residual")
N_MESSAGE_STATS = len(MESSAGE_STAT_NAMES)


def message_stats(acts_sent: jnp.ndarray) -> jnp.ndarray:
    """Per-batch anomaly statistics of a transmitted cut-activation message,
    computed from exactly what the AP observes (the post-tamper message):

      * ``dispersion`` — mean distance of the batch's samples from the batch
        mean, relative to the mean's norm.  A replayed message (one captured
        activation re-transmitted for the whole batch) has dispersion 0.
      * ``support_residual`` — norm fraction of the message outside the
        honest activation support (the paper's CNN cut layers are ReLU, so
        honest messages are non-negative; a noise blend leaves the support).
        Architectures without a constrained cut support yield near-equal
        residuals for every client, making the z-scored feature inert.

    These are the ``loss_plus_distance`` selection policy's activation
    distances (``repro.selection``): final-model validation activations carry
    no stealth/replay signal at small scale, but the training messages do.
    Returns a ``(N_MESSAGE_STATS,)`` f32 vector."""
    flat = acts_sent.reshape(acts_sent.shape[0], -1).astype(jnp.float32)
    mu = jnp.mean(flat, axis=0, keepdims=True)
    mu_norm = jnp.maximum(jnp.linalg.norm(mu), 1e-12)
    disp = jnp.mean(jnp.linalg.norm(flat - mu, axis=1)) / mu_norm
    total = jnp.maximum(jnp.linalg.norm(flat), 1e-12)
    support = jnp.linalg.norm(jnp.minimum(flat, 0.0)) / total
    return jnp.stack([disp, support])


# ---------------------------------------------------------------------------
# the SL mini-batch exchange with attack hooks
# ---------------------------------------------------------------------------

def _sl_exchange(module: SplitModule, gamma: Pytree, phi: Pytree,
                 x: jnp.ndarray, y: jnp.ndarray, key: jax.Array,
                 poison, send_labels, send_acts, recv_grad,
                 with_stats: bool = False, quant: Optional[str] = None):
    """One FwdProp/BackProp exchange.  Returns (g_gamma, g_phi, loss), plus
    the transmitted message's :func:`message_stats` when ``with_stats``.

    The attack hooks sit exactly where the taxonomy places them:
      * ``poison``: the client's own training inputs, before the forward
                                                    (backdoor trigger stamping)
      * ``send_labels``: labels tampered before transmission
                                                    (label flipping, backdoor)
      * ``send_acts``: cut activations tampered before transmission
                                                    (activation tampering, replay)
      * ``recv_grad``: cut gradient tampered after reception
                                                    (gradient scaling/noise)

    The per-exchange key splits into an activation-side and a gradient-side
    stream so stochastic attacks on either leg draw independent noise.

    ``quant`` compresses the two cut-layer wire messages through the
    ``kernels/quant_exchange`` round trip (per-sample symmetric int8 /
    fp8-e4m3, one f32 scale per row).  The transform models the physical
    wire: sender-side attacks (``send_acts``) apply *before* transmission and
    then quantize with the message — so the AP observes, scores and
    backpropagates through exactly the dequantized message a real receiver
    would reconstruct — while the client-side ``recv_grad`` hook applies
    *after* the cut gradient is dequantized.  Under ``with_stats`` the fused
    kernel emits :func:`message_stats` of that dequantized uplink message in
    the same pass, so anomaly scores stay free.

    Single source of truth for the four-message exchange: the static
    (per-``Attack``) and vectorised (per-``AttackVec``) entry points below
    differ only in which hook implementations they bind, so the engines'
    bit-for-bit equivalence contract cannot drift between two copies.
    """
    from ..kernels import ops as kops
    k_act, k_grad = jax.random.split(key)
    x_used = poison(x)
    y_sent = send_labels(y)

    acts, client_vjp = jax.vjp(lambda g: module.client_forward(g, x_used), gamma)
    acts_sent = send_acts(acts, k_act)
    stats = None
    if quant is not None:
        flat = acts_sent.reshape(acts_sent.shape[0], -1).astype(jnp.float32)
        if with_stats:
            deq, _, stats = kops.quant_roundtrip_stats(flat, quant)
        else:
            deq, _ = kops.quant_roundtrip(flat, quant)
        acts_sent = deq.reshape(acts_sent.shape).astype(acts_sent.dtype)

    def ap_fn(phi_, acts_):
        return module.ap_loss(phi_, acts_, y_sent)

    loss, ap_grads = jax.value_and_grad(ap_fn, argnums=(0, 1))(phi, acts_sent)
    g_phi, g_acts = ap_grads

    if quant is not None:
        gflat = g_acts.reshape(g_acts.shape[0], -1).astype(jnp.float32)
        gdeq, _ = kops.quant_roundtrip(gflat, quant)
        g_acts = gdeq.reshape(g_acts.shape).astype(g_acts.dtype)
    g_acts_recv = recv_grad(g_acts, k_grad)
    (g_gamma,) = client_vjp(g_acts_recv.astype(acts.dtype))
    if with_stats:
        if stats is None:
            stats = message_stats(acts_sent)
        return g_gamma, g_phi, loss, stats
    return g_gamma, g_phi, loss


def sl_minibatch_grads(module: SplitModule, attack: Attack, gamma: Pytree, phi: Pytree,
                       x: jnp.ndarray, y: jnp.ndarray, key: jax.Array,
                       with_stats: bool = False, quant: Optional[str] = None):
    """The exchange with a static ``Attack`` (one compiled program per spec)."""
    return _sl_exchange(
        module, gamma, phi, x, y, key,
        lambda x_: poison_inputs(attack, x_),
        lambda y_: flip_labels(attack, y_, module.n_classes),
        lambda a, k: tamper_activation(attack, a, k),
        lambda g, k: tamper_gradient(attack, g, k),
        with_stats=with_stats, quant=quant)


def sgd_update(params: Pytree, grads: Pytree, lr: float) -> Pytree:
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)


def _client_update(grads_fn, gamma: Pytree, phi: Pytree,
                   data: Tuple[jnp.ndarray, jnp.ndarray], lr: float,
                   key: jax.Array, with_stats: bool = False):
    """E mini-batch SGD updates for one client (lines 10-18 of Algorithm 1),
    generic over the exchange implementation.

    data = (xs, ys) with xs: (E, B, ...), ys: (E, B, ...).  With
    ``with_stats`` additionally returns the client's mean per-batch
    :func:`message_stats` vector (the ``grads_fn`` must return 4-tuples).
    """
    xs, ys = data

    def step(carry, inputs):
        gamma, phi, k = carry
        x, y = inputs
        k, sub = jax.random.split(k)
        out = grads_fn(gamma, phi, x, y, sub)
        g_gamma, g_phi, loss = out[:3]
        aux = (loss, out[3]) if with_stats else loss
        return (sgd_update(gamma, g_gamma, lr), sgd_update(phi, g_phi, lr), k), aux

    (gamma, phi, _), aux = jax.lax.scan(step, (gamma, phi, key), (xs, ys))
    if with_stats:
        losses, stats = aux
        return gamma, phi, jnp.mean(losses), jnp.mean(stats, axis=0)
    return gamma, phi, jnp.mean(aux)


@partial(jax.jit, static_argnums=(0, 1, 5), static_argnames=("quant",))
def client_update(module: SplitModule, attack: Attack, gamma: Pytree, phi: Pytree,
                  data: Tuple[jnp.ndarray, jnp.ndarray], lr: float, key: jax.Array,
                  *, quant: Optional[str] = None
                  ) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    return _client_update(partial(sl_minibatch_grads, module, attack, quant=quant),
                          gamma, phi, data, lr, key)


@partial(jax.jit, static_argnums=(0, 1, 5), static_argnames=("quant",))
def client_update_stats(module: SplitModule, attack: Attack, gamma: Pytree,
                        phi: Pytree, data: Tuple[jnp.ndarray, jnp.ndarray],
                        lr: float, key: jax.Array, *,
                        quant: Optional[str] = None):
    """:func:`client_update` + the client's mean transmitted-message
    statistics — the sequential oracle's path for selection policies that
    score activation-message anomalies.  The parameter/loss arithmetic is
    bit-identical to :func:`client_update` (the stats ride alongside the
    same scan)."""
    return _client_update(
        partial(sl_minibatch_grads, module, attack, with_stats=True, quant=quant),
        gamma, phi, data, lr, key, with_stats=True)


# ---------------------------------------------------------------------------
# vectorised (vmappable) variants — the same exchange with the attack
# configuration as traced data instead of a static jit argument, so one
# compiled program serves every (cluster, client, attack) slot of the batched
# engine.  Honest slots reproduce ``client_update`` bit-for-bit: every tamper
# site is a ``jnp.where`` whose false branch is the untouched message.
# ---------------------------------------------------------------------------

def sl_minibatch_grads_vec(module: SplitModule, av: AttackVec, gamma: Pytree,
                           phi: Pytree, x: jnp.ndarray, y: jnp.ndarray,
                           key: jax.Array, with_stats: bool = False,
                           quant: Optional[str] = None):
    return _sl_exchange(
        module, gamma, phi, x, y, key,
        lambda x_: poison_inputs_vec(av, x_),
        lambda y_: flip_labels_vec(av, y_, module.n_classes),
        lambda a, k: tamper_activation_vec(av, a, k),
        lambda g, k: tamper_gradient_vec(av, g, k),
        with_stats=with_stats, quant=quant)


def client_update_vec_impl(module: SplitModule, av: AttackVec, gamma: Pytree,
                           phi: Pytree, data: Tuple[jnp.ndarray, jnp.ndarray],
                           lr: float, key: jax.Array, *,
                           quant: Optional[str] = None
                           ) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    """Un-jitted body of :func:`client_update_vec` — the batched engine embeds
    it inside its own jitted round program (vmap over clusters, scan over the
    within-cluster client chain)."""
    return _client_update(partial(sl_minibatch_grads_vec, module, av, quant=quant),
                          gamma, phi, data, lr, key)


def client_update_vec_stats_impl(module: SplitModule, av: AttackVec,
                                 gamma: Pytree, phi: Pytree,
                                 data: Tuple[jnp.ndarray, jnp.ndarray],
                                 lr: float, key: jax.Array, *,
                                 quant: Optional[str] = None):
    """:func:`client_update_vec_impl` + mean message statistics (the batched
    engines' path for message-anomaly selection policies)."""
    return _client_update(
        partial(sl_minibatch_grads_vec, module, av, with_stats=True, quant=quant),
        gamma, phi, data, lr, key, with_stats=True)


client_update_vec = partial(jax.jit, static_argnums=(0, 5),
                            static_argnames=("quant",))(client_update_vec_impl)
