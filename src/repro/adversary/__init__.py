"""Pluggable adversary subsystem.

Registry-based attack families (``families``), round-indexed schedules
(``schedule``) and per-client threat models (``threat_model``), each with a
static reference form for the sequential oracle and a compilation into the
extended vmappable :class:`AttackVec` for the batched engine.
"""
from . import families as _families  # noqa: F401  (populates the registry)
from .registry import (AttackFamily, AttackVec, attack_vec, attack_vec_grid,
                       families, flip_labels, flip_labels_vec, get,
                       poison_inputs, poison_inputs_vec, register,
                       scale_attack, tamper_activation, tamper_activation_vec,
                       tamper_gradient, tamper_gradient_vec, tamper_params)
from .schedule import (ALWAYS, SCHEDULE_KINDS, Schedule, after_warmup,
                       every_k, ramp)
from .specs import (ACTIVATION, BACKDOOR, GRAD_NOISE, GRAD_SCALE, GRADIENT,
                    HONEST, KINDS, LABEL_FLIP, NONE, PARAM_TAMPER, REPLAY,
                    STEALTH, Attack, stealth)
from .threat_model import (ClientThreat, ThreatModel, resolve_threat_model)

__all__ = [
    "Attack", "HONEST", "stealth", "KINDS",
    "NONE", "LABEL_FLIP", "ACTIVATION", "GRADIENT", "PARAM_TAMPER",
    "BACKDOOR", "GRAD_SCALE", "GRAD_NOISE", "REPLAY", "STEALTH",
    "Schedule", "SCHEDULE_KINDS", "ALWAYS", "every_k", "after_warmup", "ramp",
    "ClientThreat", "ThreatModel", "resolve_threat_model",
    "AttackFamily", "AttackVec", "register", "get", "families", "scale_attack",
    "attack_vec", "attack_vec_grid",
    "poison_inputs", "flip_labels", "tamper_activation", "tamper_gradient",
    "tamper_params", "poison_inputs_vec", "flip_labels_vec",
    "tamper_activation_vec", "tamper_gradient_vec",
]
