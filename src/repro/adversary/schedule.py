"""Round-indexed attack schedules.

A :class:`Schedule` maps the global round index t to an activation *strength*
in [0, 1] — 0 means the client behaves honestly this round, 1 means the full
attack, and fractional values interpolate the attack's continuous parameters
toward honest (see each family's ``scale`` rule in
``repro.adversary.families``).  Schedules are frozen data: the protocol
evaluates them on the host each round and folds the result into the
:class:`~repro.adversary.registry.AttackVec` parameter lanes, so the batched
engine's compiled round program never changes shape — one compile serves
every schedule.

Four kinds (the intermittent/adaptive adversaries of arXiv:2505.05872 and
arXiv:2212.01716 that a static always-on harness never exercises):

  * ``always``   active every round (the legacy behaviour)
  * ``every_k``  active on rounds t with (t - offset) % k == 0 and t >= offset
  * ``warmup``   off until round ``start``, then always on (on/off flips with
                 ``stop`` to model an attacker that goes quiet again)
  * ``ramp``     strength grows linearly from 0 over ``ramp_rounds`` rounds
                 starting at ``start``
"""
from __future__ import annotations

import dataclasses

ALWAYS_KIND = "always"
EVERY_K_KIND = "every_k"
WARMUP_KIND = "warmup"
RAMP_KIND = "ramp"

SCHEDULE_KINDS = (ALWAYS_KIND, EVERY_K_KIND, WARMUP_KIND, RAMP_KIND)


@dataclasses.dataclass(frozen=True)
class Schedule:
    kind: str = ALWAYS_KIND
    k: int = 2                # every_k: period
    offset: int = 0           # every_k: phase (first active round)
    start: int = 0            # warmup/ramp: first (partially) active round
    stop: int = -1            # warmup: first round the attack goes quiet again (-1 = never)
    ramp_rounds: int = 5      # ramp: rounds to reach full strength

    def __post_init__(self):
        assert self.kind in SCHEDULE_KINDS, self.kind
        assert self.k >= 1 and self.ramp_rounds >= 1

    def strength(self, t: int) -> float:
        """Attack strength in [0, 1] at global round t (host-side, exact)."""
        if self.kind == ALWAYS_KIND:
            return 1.0
        if self.kind == EVERY_K_KIND:
            return 1.0 if t >= self.offset and (t - self.offset) % self.k == 0 else 0.0
        if self.kind == WARMUP_KIND:
            on = t >= self.start and (self.stop < 0 or t < self.stop)
            return 1.0 if on else 0.0
        # ramp
        if t < self.start:
            return 0.0
        return min(1.0, (t - self.start + 1) / self.ramp_rounds)

    def active(self, t: int) -> bool:
        return self.strength(t) > 0.0


ALWAYS = Schedule()


def every_k(k: int, offset: int = 0) -> Schedule:
    """Intermittent attacker: strikes every k-th round (phase ``offset``)."""
    return Schedule(EVERY_K_KIND, k=k, offset=offset)


def after_warmup(start: int, stop: int = -1) -> Schedule:
    """Sleeper attacker: honest during warmup, on from round ``start``
    (optionally quiet again from ``stop``)."""
    return Schedule(WARMUP_KIND, start=start, stop=stop)


def ramp(ramp_rounds: int, start: int = 0) -> Schedule:
    """Escalating attacker: strength climbs linearly to 1 over
    ``ramp_rounds`` rounds."""
    return Schedule(RAMP_KIND, ramp_rounds=ramp_rounds, start=start)
