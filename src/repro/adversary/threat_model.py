"""Threat models: who attacks, with what, and when.

A :class:`ThreatModel` maps each malicious client id to a
:class:`ClientThreat` — an (attack, schedule) pair.  It is the single object
the protocol drivers consume; the legacy ``(malicious, attack)`` API is
bridged through :meth:`ThreatModel.from_legacy` (every listed client gets the
same always-on attack), so existing call sites keep working unchanged.

Both engines derive their attack state from the same source of truth:

  * the sequential oracle asks :meth:`attack_for` per (client, round) and
    jit-specialises on the returned frozen spec;
  * the batched engine asks :meth:`attack_vec_for_clusters` per round, which
    calls the *same* ``attack_for`` per slot and compiles the resulting
    (already schedule-scaled) specs into one extended
    :class:`~repro.adversary.registry.AttackVec` — data, not program, so
    heterogeneous mixtures and time-varying schedules reuse a single
    compiled round program.

Note the asymmetry this buys: a ``ramp`` schedule creates one *sequential*
jit specialisation per distinct strength (the oracle is the correctness
reference, not the fast path) but exactly one *batched* program.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Mapping, Optional, Sequence, Set, Union

from .registry import AttackVec, attack_vec_grid, get, scale_attack
from .schedule import ALWAYS, Schedule
from .specs import HONEST, NONE, Attack


@dataclasses.dataclass(frozen=True)
class ClientThreat:
    attack: Attack
    schedule: Schedule = ALWAYS


def _as_threat(spec: Union["ClientThreat", Attack]) -> ClientThreat:
    if isinstance(spec, ClientThreat):
        return spec
    if isinstance(spec, Attack):
        return ClientThreat(spec)
    raise TypeError(f"expected Attack or ClientThreat, got {type(spec).__name__}")


@dataclasses.dataclass(frozen=True)
class ThreatModel:
    """Immutable client -> (attack, schedule) assignment.

    Construct from a mapping (clients not listed are honest)::

        tm = ThreatModel.build({
            0: Attack(LABEL_FLIP),                            # always on
            2: ClientThreat(Attack(GRAD_SCALE, grad_scale=8.0),
                            every_k(2)),                      # intermittent
        })

    or bridge from the legacy API::

        tm = ThreatModel.from_legacy(malicious={0, 2}, attack=Attack(LABEL_FLIP))
    """
    clients: Mapping[int, ClientThreat] = dataclasses.field(default_factory=dict)
    # Clients counted malicious for honesty accounting even though they mount
    # no message-level attack — the legacy API allowed marking clients
    # malicious while attack=HONEST, and History's selected_honest /
    # honest_cluster_exists bookkeeping must keep honouring that.
    marked_malicious: FrozenSet[int] = frozenset()

    @classmethod
    def build(cls, assignments: Mapping[int, Union[ClientThreat, Attack]],
              schedule: Schedule = ALWAYS) -> "ThreatModel":
        """Normalise a {client: Attack | ClientThreat} mapping; bare Attack
        values get ``schedule`` (default always-on).  HONEST entries drop."""
        out: Dict[int, ClientThreat] = {}
        for client, spec in assignments.items():
            threat = _as_threat(spec)
            if threat.attack.kind == NONE:
                continue
            if threat.schedule is ALWAYS and schedule is not ALWAYS:
                threat = ClientThreat(threat.attack, schedule)
            out[int(client)] = threat
        return cls(out)

    @classmethod
    def from_legacy(cls, malicious: Optional[Set[int]], attack: Attack = HONEST,
                    schedule: Schedule = ALWAYS) -> "ThreatModel":
        """The pre-subsystem API: one shared attack for every malicious id.
        With attack=HONEST the listed clients mount nothing but stay in the
        ``malicious`` accounting set, exactly as the legacy drivers did."""
        if not malicious:
            return cls({})
        if attack.kind == NONE:
            return cls({}, marked_malicious=frozenset(int(c) for c in malicious))
        return cls({int(c): ClientThreat(attack, schedule) for c in malicious})

    # -- bookkeeping --------------------------------------------------------

    @property
    def malicious(self) -> FrozenSet[int]:
        """All clients with an assigned attack (regardless of schedule phase)
        plus any marked-malicious ids — the paper's (static) malicious set,
        used for honesty accounting."""
        return frozenset(self.clients) | self.marked_malicious

    @property
    def has_param_tamper(self) -> bool:
        return any(get(t.attack.kind).trains_honestly
                   for t in self.clients.values())

    def describe(self) -> Dict[str, Dict[str, object]]:
        """JSON-friendly manifest (benchmark provenance)."""
        return {str(c): dict(attack=dataclasses.asdict(t.attack),
                             schedule=dataclasses.asdict(t.schedule))
                for c, t in sorted(self.clients.items())}

    # -- per-round attack state --------------------------------------------

    def attack_for(self, client: int, t: int) -> Attack:
        """The *training-phase* spec for one (client, round): HONEST for
        honest clients, schedule-inactive rounds and host-side families
        (param tamperers train honestly, Section III-C); otherwise the
        schedule-strength-scaled spec."""
        threat = self.clients.get(client)
        if threat is None or get(threat.attack.kind).trains_honestly:
            return HONEST
        return scale_attack(threat.attack, threat.schedule.strength(t))

    def param_attack_for(self, client: int, t: int) -> Optional[Attack]:
        """The handoff-tampering spec for one (client, round), or None —
        consumed host-side by the selection loop, never compiled."""
        threat = self.clients.get(client)
        if threat is None or not get(threat.attack.kind).trains_honestly:
            return None
        a = scale_attack(threat.attack, threat.schedule.strength(t))
        return None if a.kind == NONE else a

    def attack_vec_for_clusters(self, clusters: Sequence[Sequence[int]],
                                t: int) -> AttackVec:
        """(R, M_bar)-leaved AttackVec for round t's cluster partition,
        compiled from exactly the specs ``attack_for`` hands the sequential
        oracle — the engines' equivalence contract reduces to the kernel
        arithmetic."""
        return attack_vec_grid([[self.attack_for(c, t) for c in cluster]
                                for cluster in clusters])


def resolve_threat_model(malicious: Optional[Set[int]], attack: Attack,
                         threat_model: Optional[ThreatModel]) -> ThreatModel:
    """Protocol-driver argument resolution: either the legacy
    ``(malicious, attack)`` pair or an explicit ``threat_model``, not both."""
    if threat_model is not None:
        if malicious or attack.kind != NONE:
            raise ValueError("pass either threat_model or the legacy "
                             "(malicious, attack) pair, not both")
        return threat_model
    return ThreatModel.from_legacy(malicious, attack)
