"""Attack specifications: the frozen, hashable description of what a
malicious client does.

An :class:`Attack` names a *family* (see ``repro.adversary.families``) plus
the family's parameters.  It is deliberately a single flat frozen dataclass —
hashable, so the sequential oracle can use it as a static jit argument (one
compiled program per distinct spec), and trivially serialisable for benchmark
manifests.  Parameters a family does not use are simply ignored by its
registry entry.

Families
--------
The paper's three message-level attacks (Section II / V-A) plus Section
III-C's parameter tampering:

  * ``label_flip``    y -> (y + label_shift) mod n_classes
  * ``activation``    g -> act_keep * g + (1 - act_keep) * n~   (norm-matched noise)
  * ``gradient``      grad_c -> grad_scale * grad_c             (paper: -1, sign flip)
  * ``param_tamper``  handed-off gamma += param_scale * N(0, I) (trains honestly)

and the extended threat catalogue (arXiv:2505.05872 taxonomy):

  * ``backdoor``      stamp a trigger patch on the inputs, relabel to ``target``
  * ``grad_scale``    Byzantine gradient scaling (same kernel as ``gradient``;
                      a separate name so sweeps can distinguish sign-flip from
                      amplification)
  * ``grad_noise``    grad_c += noise_std * N(0, I)
  * ``replay``        re-transmit one captured cut-activation message for the
                      whole batch (stale/replayed activations)
  * ``stealth``       the activation blend with act_keep near 1, tuned to
                      hover near the validation-selection threshold (use the
                      :func:`stealth` constructor)
"""
from __future__ import annotations

import dataclasses

# -- family names -----------------------------------------------------------
NONE = "none"
LABEL_FLIP = "label_flip"
ACTIVATION = "activation"
GRADIENT = "gradient"
PARAM_TAMPER = "param_tamper"       # Section III-C: tampering the handed-off params
BACKDOOR = "backdoor"
GRAD_SCALE = "grad_scale"
GRAD_NOISE = "grad_noise"
REPLAY = "replay"
STEALTH = "stealth"

KINDS = (NONE, LABEL_FLIP, ACTIVATION, GRADIENT, PARAM_TAMPER,
         BACKDOOR, GRAD_SCALE, GRAD_NOISE, REPLAY, STEALTH)


@dataclasses.dataclass(frozen=True)
class Attack:
    kind: str = NONE
    label_shift: int = 3             # label_flip: shift amount
    act_keep: float = 0.1            # activation/stealth: fraction of the true activation kept
    param_scale: float = 5.0         # param_tamper: noise multiplier on handoff
    grad_scale: float = -1.0         # gradient/grad_scale: cut-gradient multiplier
    noise_std: float = 1.0           # grad_noise: Gaussian std added to the cut gradient
    target: int = 0                  # backdoor: the targeted label
    trigger_frac: float = 0.05       # backdoor: fraction of input features the trigger stamps
    trigger_value: float = 2.0       # backdoor: the stamped value

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


HONEST = Attack(NONE)


def stealth(keep: float = 0.97) -> Attack:
    """The strength-parameterised stealth variant: an activation blend that
    keeps ``keep`` of the true message, perturbing the cluster's validation
    loss just enough to sometimes slip past argmin selection (``keep`` near 1
    hovers near the selection threshold; the plain ``activation`` family's
    default 0.1 is the loud version)."""
    return Attack(STEALTH, act_keep=keep)
