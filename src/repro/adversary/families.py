"""Built-in attack families.

Each family is registered with its static reference transforms (the
sequential oracle's ground truth) and its AttackVec compilation (kind code +
parameter lanes read by the shared vec kernels).  Static and vec forms of a
family share one arithmetic helper wherever the math is non-trivial, so the
engines' bit-for-bit equivalence contract cannot drift between two copies.

Importing this module populates ``repro.adversary.registry.REGISTRY``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import specs
from .registry import (CODE_ACTIVATION, CODE_BACKDOOR, CODE_GRAD_NOISE,
                       CODE_GRAD_SCALE, CODE_LABEL_FLIP, CODE_REPLAY,
                       AttackFamily, register)
from .specs import Attack


# ---------------------------------------------------------------------------
# shared arithmetic helpers
# ---------------------------------------------------------------------------

def _noise_blend(acts: jnp.ndarray, key: jax.Array, keep) -> jnp.ndarray:
    """Keep a ``keep`` fraction of the true cut activation and replace the
    rest with Gaussian noise norm-matched per sample (leading axis = batch).
    ``keep`` is coerced to f32 up front so the static (python-float) and vec
    (f32-lane) paths run bit-identical arithmetic — 1 - keep in float64
    rounds differently."""
    keep = jnp.float32(keep)
    n = jax.random.normal(key, acts.shape, jnp.float32)
    axes = tuple(range(1, acts.ndim))
    g_norm = jnp.sqrt(jnp.sum(jnp.square(acts.astype(jnp.float32)), axis=axes, keepdims=True))
    n_norm = jnp.sqrt(jnp.sum(jnp.square(n), axis=axes, keepdims=True))
    n_scaled = n * (g_norm / jnp.maximum(n_norm, 1e-12))
    out = keep * acts.astype(jnp.float32) + (1.0 - keep) * n_scaled
    return out.astype(acts.dtype)


def _replay_acts(acts: jnp.ndarray) -> jnp.ndarray:
    """Stale/replay: re-transmit the first sample's captured cut-activation
    message for every sample of the batch."""
    return jnp.broadcast_to(acts[:1], acts.shape).astype(acts.dtype)


def _stamp_trigger(x: jnp.ndarray, frac, value) -> jnp.ndarray:
    """Backdoor trigger: overwrite the first ``round(frac * d)`` features of
    each flattened sample with ``value``.  ``frac``/``value`` may be python
    floats (static path) or traced f32 lanes (vec path) — the feature count d
    is static either way, so both paths lower to the same masked write."""
    flat = x.reshape(x.shape[0], -1)
    d = flat.shape[1]
    k = jnp.maximum(1, jnp.round(jnp.float32(frac) * d)).astype(jnp.int32)
    mask = jnp.arange(d) < k
    flat = jnp.where(mask[None, :], jnp.float32(value).astype(x.dtype), flat)
    return flat.reshape(x.shape)


def _grad_noise(g: jnp.ndarray, key, std) -> jnp.ndarray:
    assert key is not None, "the grad_noise family needs the gradient-side key"
    return (g.astype(jnp.float32)
            + std * jax.random.normal(key, g.shape, jnp.float32)).astype(g.dtype)


# ---------------------------------------------------------------------------
# continuous-parameter ramp rules
# ---------------------------------------------------------------------------

def _scale_keep(a: Attack, s: float) -> Attack:
    return dataclasses.replace(a, act_keep=1.0 - (1.0 - a.act_keep) * s)


def _scale_grad(a: Attack, s: float) -> Attack:
    return dataclasses.replace(a, grad_scale=1.0 + (a.grad_scale - 1.0) * s)


def _scale_noise(a: Attack, s: float) -> Attack:
    return dataclasses.replace(a, noise_std=a.noise_std * s)


def _scale_param(a: Attack, s: float) -> Attack:
    return dataclasses.replace(a, param_scale=a.param_scale * s)


# ---------------------------------------------------------------------------
# the families
# ---------------------------------------------------------------------------

register(AttackFamily(
    name=specs.NONE, code=0, doc="honest client"))


register(AttackFamily(
    name=specs.LABEL_FLIP, code=CODE_LABEL_FLIP,
    doc="y -> (y + shift) mod n_classes on the transmitted labels",
    static_labels=lambda a, y, n: (y + a.label_shift) % n,
    vec_labels=lambda av, y, n: (y + av.shift) % n,
    lanes=lambda a: dict(shift=a.label_shift),
))


def _act_family(name: str, doc: str) -> AttackFamily:
    return AttackFamily(
        name=name, code=CODE_ACTIVATION, doc=doc,
        static_acts=lambda a, acts, k: _noise_blend(acts, k, a.act_keep),
        vec_acts=lambda av, acts, k: _noise_blend(acts, k, av.act_keep.astype(jnp.float32)),
        lanes=lambda a: dict(act_keep=a.act_keep),
        scale=_scale_keep,
    )


register(_act_family(
    specs.ACTIVATION,
    "norm-matched Gaussian blend of the cut-activation message (paper V-A)"))

# Stealth compiles onto the activation kernel: same arithmetic, but a spec
# whose default keep (see specs.stealth) sits near the selection threshold.
register(_act_family(
    specs.STEALTH,
    "activation blend with keep near 1 — hovers at the validation-selection "
    "threshold instead of announcing itself"))


def _grad_family(name: str, doc: str) -> AttackFamily:
    return AttackFamily(
        name=name, code=CODE_GRAD_SCALE, doc=doc,
        static_grads=lambda a, g, k: (a.grad_scale * g.astype(jnp.float32)).astype(g.dtype),
        vec_grads=lambda av, g, k: (av.grad_scale * g.astype(jnp.float32)).astype(g.dtype),
        lanes=lambda a: dict(grad_scale=a.grad_scale),
        scale=_scale_grad,
    )


# The paper's gradient tampering (grad_scale defaults to -1: sign reversal)
# and its Byzantine generalisation share one kernel; the separate names keep
# sweep manifests honest about which threat was meant.
register(_grad_family(
    specs.GRADIENT, "grad_c -> grad_scale * grad_c (paper: -1, sign flip)"))
register(_grad_family(
    specs.GRAD_SCALE, "Byzantine gradient scaling (arbitrary multiplier)"))


register(AttackFamily(
    name=specs.GRAD_NOISE, code=CODE_GRAD_NOISE,
    doc="grad_c += noise_std * N(0, I) on the received cut gradient",
    static_grads=lambda a, g, k: _grad_noise(g, k, a.noise_std),
    vec_grads=lambda av, g, k: _grad_noise(g, k, av.noise_std),
    grads_need_key=True,
    lanes=lambda a: dict(noise_std=a.noise_std),
    scale=_scale_noise,
))


register(AttackFamily(
    name=specs.BACKDOOR, code=CODE_BACKDOOR,
    doc="stamp a trigger patch on the inputs and relabel them to the target",
    static_poison=lambda a, x: _stamp_trigger(x, a.trigger_frac, a.trigger_value),
    static_labels=lambda a, y, n: jnp.full_like(y, a.target % n),
    vec_poison=lambda av, x: _stamp_trigger(x, av.trig_frac, av.trig_value),
    vec_labels=lambda av, y, n: jnp.broadcast_to(av.target % n, y.shape).astype(y.dtype),
    lanes=lambda a: dict(target=a.target, trig_frac=a.trigger_frac,
                         trig_value=a.trigger_value),
))


register(AttackFamily(
    name=specs.REPLAY, code=CODE_REPLAY,
    doc="replay one captured cut-activation message for the whole batch",
    static_acts=lambda a, acts, k: _replay_acts(acts),
    vec_acts=lambda av, acts, k: _replay_acts(acts),
))


def _tamper_params(a: Attack, params, key: jax.Array):
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))
    tampered = [l + a.param_scale * jax.random.normal(k, l.shape, l.dtype)
                for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, tampered)


register(AttackFamily(
    name=specs.PARAM_TAMPER, code=0, trains_honestly=True,
    doc="train honestly, hand off gamma += param_scale * N(0, I) (III-C)",
    static_params=_tamper_params,
    scale=_scale_param,
))
