"""The attack-family registry and the two execution forms of every family.

Each registered :class:`AttackFamily` declares

  (a) **static reference transforms** — one hook per tampering point of the
      SL message exchange, taking the frozen :class:`~repro.adversary.specs.
      Attack` spec.  The sequential oracle jit-specialises on the spec, so
      these are the ground truth the batched engine is tested against; and

  (b) **a compilation into the extended** :class:`AttackVec` — a per-slot
      integer *kind code* plus float/int *parameter lanes*.  The vectorised
      transforms below select each family's arithmetic with
      ``jnp.where(code == ...)``, so an arbitrary heterogeneous per-client
      mixture of families (and per-round schedule strengths) runs as ONE
      jitted batched program; honest slots (code 0) reproduce the untouched
      messages bit-for-bit.

The four tampering points (``repro.core.split._sl_exchange``):

  * ``poison``  — the client's own training inputs, before the forward pass
  * ``labels``  — the label message sent to the AP
  * ``acts``    — the cut-activation message sent to the AP
  * ``grads``   — the cut-gradient message received from the AP

plus the host-side ``params`` hook for handoff tampering (Section III-C).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .specs import Attack, HONEST

Pytree = Any

# -- vec kind codes (0 = honest / no message-level effect) ------------------
CODE_NONE = 0
CODE_LABEL_FLIP = 1
CODE_ACTIVATION = 2
CODE_GRAD_SCALE = 3
CODE_GRAD_NOISE = 4
CODE_BACKDOOR = 5
CODE_REPLAY = 6


class AttackVec(NamedTuple):
    """Vmappable attack state: every leaf carries arbitrary leading batch
    axes — (M_bar,) per cluster, (R, M_bar) per round, (S, R, M_bar) per
    seed sweep.  ``code`` is the per-slot family kind code; the remaining
    leaves are the parameter lanes the family kernels read."""
    code: jnp.ndarray        # int32  — vec kind code (CODE_*)
    shift: jnp.ndarray       # int32  — label-flip shift
    act_keep: jnp.ndarray    # float32 — activation/stealth keep fraction
    grad_scale: jnp.ndarray  # float32 — cut-gradient multiplier
    noise_std: jnp.ndarray   # float32 — cut-gradient Gaussian std
    target: jnp.ndarray      # int32  — backdoor target label
    trig_frac: jnp.ndarray   # float32 — backdoor trigger size (input fraction)
    trig_value: jnp.ndarray  # float32 — backdoor trigger stamp value

    # Back-compat views of the pre-registry boolean lanes.
    @property
    def flip(self):
        return self.code == CODE_LABEL_FLIP

    @property
    def act(self):
        return self.code == CODE_ACTIVATION

    @property
    def grad(self):
        return self.code == CODE_GRAD_SCALE


_LANE_DEFAULTS = dict(code=0, shift=0, act_keep=1.0, grad_scale=1.0,
                      noise_std=0.0, target=0, trig_frac=0.0, trig_value=0.0)
_LANE_DTYPES = dict(code=np.int32, shift=np.int32, act_keep=np.float32,
                    grad_scale=np.float32, noise_std=np.float32,
                    target=np.int32, trig_frac=np.float32,
                    trig_value=np.float32)


@dataclasses.dataclass(frozen=True)
class AttackFamily:
    """One attack family: static reference hooks + AttackVec compilation.

    ``static_*`` hooks take the frozen Attack spec; ``vec_*`` hooks take an
    AttackVec whose lanes are per-slot scalars inside the batched engine's
    vmap/scan.  ``lanes`` maps a spec to the parameter-lane values its vec
    kernels read.  ``scale`` interpolates the spec toward honest for
    fractional schedule strengths (continuous families only; discrete
    families gate at strength > 0).  ``trains_honestly`` marks host-side
    families (param_tamper) whose training-phase behaviour is honest."""
    name: str
    code: int
    doc: str = ""
    static_poison: Optional[Callable] = None   # (attack, x) -> x
    static_labels: Optional[Callable] = None   # (attack, y, n_classes) -> y
    static_acts: Optional[Callable] = None     # (attack, acts, key) -> acts
    static_grads: Optional[Callable] = None    # (attack, g, key) -> g
    static_params: Optional[Callable] = None   # (attack, params, key) -> params
    vec_poison: Optional[Callable] = None      # (av, x) -> x
    vec_labels: Optional[Callable] = None      # (av, y, n_classes) -> y
    vec_acts: Optional[Callable] = None        # (av, acts, key) -> acts
    vec_grads: Optional[Callable] = None       # (av, g, key) -> g
    grads_need_key: bool = False               # vec_grads draws randomness from key
    lanes: Callable[[Attack], Dict[str, float]] = lambda a: {}
    scale: Callable[[Attack, float], Attack] = lambda a, s: a
    trains_honestly: bool = False


REGISTRY: Dict[str, AttackFamily] = {}


def register(family: AttackFamily) -> AttackFamily:
    assert family.name not in REGISTRY, f"duplicate attack family {family.name}"
    REGISTRY[family.name] = family
    return family


def get(kind: str) -> AttackFamily:
    try:
        return REGISTRY[kind]
    except KeyError:
        raise KeyError(f"unknown attack family {kind!r}; registered: "
                       f"{sorted(REGISTRY)}") from None


def families() -> Dict[str, AttackFamily]:
    return dict(REGISTRY)


def scale_attack(attack: Attack, s: float) -> Attack:
    """Schedule-strength interpolation toward honest.  s >= 1 returns the
    spec unchanged (object-identical, so the sequential oracle's jit cache
    sees one entry per base spec on always-on schedules); s <= 0 is fully
    honest; fractional s delegates to the family's ``scale`` rule."""
    if s >= 1.0:
        return attack
    if s <= 0.0:
        return HONEST
    return get(attack.kind).scale(attack, s)


# ---------------------------------------------------------------------------
# static dispatchers (the sequential oracle's reference transforms)
# ---------------------------------------------------------------------------

def poison_inputs(attack: Attack, x: jnp.ndarray) -> jnp.ndarray:
    hook = get(attack.kind).static_poison
    return hook(attack, x) if hook else x


def flip_labels(attack: Attack, y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    hook = get(attack.kind).static_labels
    return hook(attack, y, n_classes) if hook else y


def tamper_activation(attack: Attack, acts: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    hook = get(attack.kind).static_acts
    return hook(attack, acts, key) if hook else acts


def tamper_gradient(attack: Attack, g: jnp.ndarray,
                    key: Optional[jax.Array] = None) -> jnp.ndarray:
    hook = get(attack.kind).static_grads
    return hook(attack, g, key) if hook else g


def tamper_params(attack: Attack, params: Pytree, key: jax.Array) -> Pytree:
    """Section III-C: the malicious *last* client of the selected cluster
    hands off manipulated client-side parameters to the next round."""
    hook = get(attack.kind).static_params
    return hook(attack, params, key) if hook else params


# ---------------------------------------------------------------------------
# vectorised dispatchers: jnp.where chains over the registered kind codes
# ---------------------------------------------------------------------------

def _vec_stage(stage: str, skip_keyed: bool = False):
    """Unique (code, kernel) pairs for one tampering point, in code order.
    Families sharing a code (e.g. stealth compiles onto the activation
    kernel) contribute it once — the chains are unrolled at trace time, so
    the registry fully determines the single compiled program."""
    seen: Dict[int, Callable] = {}
    for fam in REGISTRY.values():
        fn = getattr(fam, stage)
        if skip_keyed and fam.grads_need_key:
            continue
        if fam.code and fn is not None and fam.code not in seen:
            seen[fam.code] = fn
    return sorted(seen.items())


def poison_inputs_vec(av: AttackVec, x: jnp.ndarray) -> jnp.ndarray:
    out = x
    for code, fn in _vec_stage("vec_poison"):
        out = jnp.where(av.code == code, fn(av, x), out)
    return out


def flip_labels_vec(av: AttackVec, y: jnp.ndarray, n_classes: int) -> jnp.ndarray:
    out = y
    for code, fn in _vec_stage("vec_labels"):
        out = jnp.where(av.code == code, fn(av, y, n_classes), out)
    return out


def tamper_activation_vec(av: AttackVec, acts: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    out = acts
    for code, fn in _vec_stage("vec_acts"):
        out = jnp.where(av.code == code, fn(av, acts, key), out)
    return out


def tamper_gradient_vec(av: AttackVec, g: jnp.ndarray,
                        key: Optional[jax.Array] = None) -> jnp.ndarray:
    """The jnp.where chain evaluates every kernel for every slot, so without
    ``key`` (the legacy 2-arg signature) the stochastic gradient kernels are
    skipped entirely — fine for key-free AttackVecs, but a grad_noise slot
    would silently pass through; the engines always supply the key."""
    out = g
    for code, fn in _vec_stage("vec_grads", skip_keyed=key is None):
        out = jnp.where(av.code == code, fn(av, g, key), out)
    return out


# ---------------------------------------------------------------------------
# AttackVec compilation
# ---------------------------------------------------------------------------

def _slot_lanes(attack: Attack) -> Dict[str, float]:
    lanes = dict(_LANE_DEFAULTS)
    fam = get(attack.kind)
    if fam.code and not fam.trains_honestly:
        lanes["code"] = fam.code
        lanes.update(fam.lanes(attack))
    return lanes


@lru_cache(maxsize=512)
def _attack_vec_grid_cached(grid: tuple) -> AttackVec:
    slots = [[_slot_lanes(a) for a in row] for row in grid]
    return AttackVec(**{
        name: jnp.asarray(np.array([[s[name] for s in row] for row in slots],
                                   dtype=_LANE_DTYPES[name]))
        for name in AttackVec._fields})


def attack_vec_grid(grid: Sequence[Sequence[Attack]]) -> AttackVec:
    """Compile an (R, M_bar) grid of per-slot specs (already
    schedule-scaled; HONEST for honest slots) into one AttackVec.

    Memoised on the spec grid: an honest or statically-malicious population
    re-derives the SAME grid every round (scheduled strengths land in the
    ``Attack`` specs, so time-varying threat models key distinct entries),
    and compiling it costs one small host->device transfer per AttackVec
    lane — measurably the single most expensive piece of per-round host
    assembly.  The cached device arrays are round inputs, never donated, so
    sharing them across rounds is safe."""
    return _attack_vec_grid_cached(tuple(tuple(row) for row in grid))


def attack_vec(attack: Attack, active) -> AttackVec:
    """Per-client attack state for a single spec.  ``active`` may be a bool
    or a bool array; param-tampering clients train honestly (Section III-C),
    so host-side families never raise a code here."""
    on = np.asarray(active, bool)
    a_lanes = _slot_lanes(attack)
    h_lanes = _slot_lanes(HONEST)
    return AttackVec(**{
        name: jnp.asarray(np.where(on, a_lanes[name], h_lanes[name])
                          .astype(_LANE_DTYPES[name]))
        for name in AttackVec._fields})
