"""``python -m repro.analysis`` — the two-layer static analyzer.

Modes:

* default / ``--check``   — run the requested layers, print open findings,
  exit nonzero if any survive the baselines (the CI gate);
* ``--update-baselines``  — regenerate the budget baselines for the cells
  measured under the current placements/device count (merge, not overwrite)
  and exit 0.  Lint suppressions are NOT auto-added: edit
  ``analysis/lint_baseline.json`` by hand and include a justification line.

Layers (``--layers``): ``lints`` (AST rules), ``programs`` (jaxpr/HLO
invariants + transfer budgets), ``compiles`` (driver compile-count budgets).
Placements (``--placements``): ``vmap,kernel`` by default; add ``sharded``
on the multi-device CI leg (sharded budget cells are keyed ``@d{N}``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from .findings import Baseline, Report, repo_root

LAYERS = ("lints", "programs", "compiles")
LINT_BASELINE = os.path.join("analysis", "lint_baseline.json")


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static program auditor + repo lint pass")
    p.add_argument("--check", action="store_true",
                   help="explicit CI-gate mode (the default behaviour)")
    p.add_argument("--update-baselines", action="store_true",
                   help="regenerate budget baselines for measured cells")
    p.add_argument("--json", metavar="PATH",
                   help="write the findings report (provenance-stamped) here")
    p.add_argument("--layers", default=",".join(LAYERS),
                   help=f"comma list of {LAYERS}")
    p.add_argument("--placements", default="vmap,kernel",
                   help="comma list of vmap,kernel,sharded")
    p.add_argument("--root", default=None,
                   help="repo root to analyze (default: this checkout)")
    return p


def run(argv: List[str] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.check and args.update_baselines:
        print("--check and --update-baselines are mutually exclusive",
              file=sys.stderr)
        return 2
    root = repo_root(args.root)
    layers = tuple(s for s in args.layers.split(",") if s)
    placements = tuple(s for s in args.placements.split(",") if s)
    for layer in layers:
        if layer not in LAYERS:
            print(f"unknown layer {layer!r} (choose from {LAYERS})",
                  file=sys.stderr)
            return 2

    report = Report(baseline=Baseline.load(os.path.join(root, LINT_BASELINE)))

    if "lints" in layers:
        from .lints import run_lints
        report.extend(run_lints(root))

    need_programs = "programs" in layers
    need_compiles = "compiles" in layers
    if need_programs or need_compiles:
        from . import budgets
        from .programs import build_context, select_cells
        ctx = build_context()
        # compile budgets FIRST: program audits would otherwise warm the
        # runner caches and zero out the deltas being measured
        if need_compiles:
            measured = budgets.measure_compile_counts(ctx, placements)
            path = budgets.budget_path(root, budgets.COMPILES_FILE)
            if args.update_baselines:
                budgets.merge_budget(path, measured)
                report.notes.append(
                    f"updated {len(measured)} compile-count cells in {path}")
            else:
                fs, notes = budgets.compare_budget(path, measured,
                                                   "compile-budget")
                report.extend(fs)
                report.notes.extend(notes)
        if need_programs:
            cells = select_cells(placements)
            rows, inv = budgets.measure_program_budgets(ctx, cells)
            report.extend(inv)
            path = budgets.budget_path(root, budgets.PROGRAMS_FILE)
            if args.update_baselines:
                budgets.merge_budget(path, rows)
                report.notes.append(
                    f"updated {len(rows)} program cells in {path}")
            else:
                fs, notes = budgets.compare_budget(path, rows,
                                                   "program-budget")
                report.extend(fs)
                report.notes.extend(notes)

    open_findings = report.open_findings
    doc = report.to_dict()
    try:
        from repro.telemetry.provenance import provenance
        doc["provenance"] = provenance(tool="repro.analysis",
                                       layers=list(layers),
                                       placements=list(placements))
    except Exception:  # noqa: BLE001 — the report must still be written
        pass
    if args.json:
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=2, sort_keys=True, default=str)
            f.write("\n")

    for note in report.notes:
        print(f"note: {note}")
    stale = doc.get("stale_suppressions", [])
    if stale:
        print(f"note: {len(stale)} stale suppression(s) in the lint "
              f"baseline can be deleted")
    for f in open_findings:
        print(f.located())
    n_sup = len(doc.get("suppressed", []))
    print(f"{len(open_findings)} open finding(s), {n_sup} suppressed "
          f"(layers={','.join(layers)}; placements={','.join(placements)})")
    if args.update_baselines:
        return 0
    return 1 if any(f.severity == "error" for f in open_findings) else 0


def main() -> None:
    sys.exit(run())
