"""Checked-in program budgets: transfer/op counts and compile counts.

Two baseline files under ``analysis/budgets/``:

* ``programs.json``       — per program cell (``repro.analysis.programs``),
  the measured :meth:`ProgramAudit.budget_row` numbers: jaxpr eqn count,
  donated/aliased counts, output arity, fetch leaves, and the compiled
  module's host-transfer/custom-call counts.  Pinning these means a future
  change cannot silently lose donation, grow the per-round fetch, or route
  the quant kernel's dequant through the host again.
* ``compile_counts.json`` — per driver x placement x block cell, how many
  new jitted programs and compiled signatures one tiny driver run creates
  (measured as ``telemetry.metrics.jit_cache_stats`` deltas in a FIXED cell
  order).  A retrace regression shows up as a signature delta above the pin.

Baselines are device-count sensitive for sharded cells (the cluster mesh
folds over the available devices), so those cell keys carry an ``@d{N}``
suffix and the files can hold e.g. ``@d1`` and ``@d8`` rows side by side.
``--update-baselines`` merges only the cells measured in this run.  A jax
version mismatch between the baseline and the running interpreter downgrades
mismatches to warnings — eqn/instruction counts legitimately drift across
compiler versions.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from .findings import Finding, make_finding

BUDGET_DIR = os.path.join("analysis", "budgets")
PROGRAMS_FILE = "programs.json"
COMPILES_FILE = "compile_counts.json"


def budget_meta() -> Dict[str, Any]:
    return {"jax": jax.__version__}


def device_suffix() -> str:
    return f"@d{len(jax.devices())}"


def cell_key(name: str, placement: str) -> str:
    """Sharded programs depend on the device count; vmap/kernel cells are
    device-independent."""
    return name + (device_suffix() if placement == "sharded" else "")


def budget_path(root: str, filename: str) -> str:
    return os.path.join(root, BUDGET_DIR, filename)


def load_budget(path: str) -> Dict[str, Any]:
    if not os.path.exists(path):
        return {"meta": {}, "cells": {}}
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def merge_budget(path: str, measured: Dict[str, Dict[str, Any]]) -> None:
    """Read-modify-write: update only the cells measured in this run, so
    baselines for other device counts survive regeneration."""
    doc = load_budget(path)
    doc["meta"] = budget_meta()
    cells = doc.setdefault("cells", {})
    cells.update(measured)
    doc["cells"] = {k: cells[k] for k in sorted(cells)}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def compare_budget(path: str, measured: Dict[str, Dict[str, Any]],
                   kind: str) -> Tuple[List[Finding], List[str]]:
    """Findings for every measured cell that deviates from the checked-in
    baseline.  ``kind`` labels the finding rule (``program-budget`` /
    ``compile-budget``)."""
    findings: List[Finding] = []
    notes: List[str] = []
    relpath = os.path.relpath(path, os.getcwd()) if os.path.isabs(path) else path
    doc = load_budget(path)
    if not doc["cells"]:
        findings.append(make_finding(
            f"{kind}-baseline-missing", "error", relpath, 0,
            f"no {kind} baseline checked in — run "
            f"`python -m repro.analysis --update-baselines` and commit",
            context=kind))
        return findings, notes

    severity = "error"
    base_jax = doc.get("meta", {}).get("jax")
    if base_jax != jax.__version__:
        severity = "warning"
        notes.append(
            f"{kind}: baseline pinned under jax {base_jax}, running "
            f"{jax.__version__} — mismatches downgraded to warnings "
            f"(regenerate with --update-baselines)")

    for key in sorted(measured):
        row = measured[key]
        base = doc["cells"].get(key)
        if base is None:
            findings.append(make_finding(
                f"{kind}-cell-missing", severity, relpath, 0,
                f"cell '{key}' has no checked-in baseline — run "
                f"--update-baselines",
                context=key))
            continue
        diffs = [f"{f}: {base.get(f)} -> {row[f]}"
                 for f in sorted(row) if base.get(f) != row[f]]
        if diffs:
            findings.append(make_finding(
                f"{kind}-mismatch", severity, relpath, 0,
                f"cell '{key}' deviates from baseline ({'; '.join(diffs)})",
                context=key))
    return findings, notes


# ---------------------------------------------------------------------------
# compile-count measurement
# ---------------------------------------------------------------------------

def _run_pigeon(ctx, placement: str, block: int):
    from repro.core.protocol import run_pigeon
    run_pigeon(ctx.module, ctx.data, ctx.pcfg, engine="batched",
               placement=placement, block=block)


def _run_splitfed(ctx, placement: str, block: int):
    from repro.core.protocol import run_splitfed
    run_splitfed(ctx.module, ctx.data, ctx.pcfg, engine="batched",
                 placement=placement, block=block)


def _run_sweep(ctx, placement: str, block: int):
    from repro.core.engine import run_pigeon_sweep
    run_pigeon_sweep(ctx.module, ctx.data, ctx.pcfg, seeds=(0, 1),
                     placement=placement, block=block)


def _run_pool(ctx, placement: str, block: int):
    import dataclasses as _dc

    from repro.core.jobs import JobSpec, run_job_pool
    specs = [JobSpec(name=f"job{s}", module=ctx.module, data=ctx.data,
                     pcfg=_dc.replace(ctx.pcfg, seed=s)) for s in (0, 1)]
    run_job_pool(specs, block=block, placement=placement)


# Fixed measurement order — the deltas are defined BY this order (a later
# cell re-using an earlier cell's compiled program is the steady state the
# budget wants to prove).
DRIVER_CELLS: List[Tuple[str, Callable]] = [
    ("pigeon/block1", lambda ctx, p: _run_pigeon(ctx, p, 1)),
    ("pigeon/block2", lambda ctx, p: _run_pigeon(ctx, p, 2)),
    ("pigeon/block2-again", lambda ctx, p: _run_pigeon(ctx, p, 2)),
    ("splitfed/block1", lambda ctx, p: _run_splitfed(ctx, p, 1)),
    ("splitfed/block2", lambda ctx, p: _run_splitfed(ctx, p, 2)),
    ("sweep/block1", lambda ctx, p: _run_sweep(ctx, p, 1)),
    ("sweep/block2", lambda ctx, p: _run_sweep(ctx, p, 2)),
    ("pool/block2", lambda ctx, p: _run_pool(ctx, p, 2)),
    ("pool/block2-again", lambda ctx, p: _run_pool(ctx, p, 2)),
]


def measure_compile_counts(ctx, placements: Tuple[str, ...]
                           ) -> Dict[str, Dict[str, int]]:
    """Run every driver cell on the tiny task and record how many new
    programs / compiled signatures / runner builds each added.  The
    ``*-again`` cells pin the steady state: a repeat run must add ZERO new
    signatures (the retrace detector)."""
    from repro.telemetry.metrics import jit_cache_stats
    rows: Dict[str, Dict[str, int]] = {}
    for placement in placements:
        if placement == "kernel":
            continue
        for name, run in DRIVER_CELLS:
            before = jit_cache_stats()
            run(ctx, placement)
            after = jit_cache_stats()
            rows[cell_key(f"{name}@{placement}", placement)] = {
                "new_programs": after["programs"] - before["programs"],
                "new_signatures": (after["program_signatures"]
                                   - before["program_signatures"]),
                "runner_builds": (after["runner_cache_misses"]
                                  - before["runner_cache_misses"]),
            }
    return rows


def measure_program_budgets(ctx, cells) -> Tuple[Dict[str, Dict[str, Any]],
                                                 List[Finding]]:
    """Audit every program cell; returns (budget rows, invariant findings)."""
    from .jaxpr_audit import audit_fn
    from .programs import expected_counts
    rows: Dict[str, Dict[str, Any]] = {}
    findings: List[Finding] = []
    for cell in cells:
        runner, (fn, args, donate) = cell.realize(ctx)
        expected_donated, expected_fetch = expected_counts(fn, args, donate)
        lowered = None
        if runner is not None:
            entry = cell.name.split("/")[1].split("@")[0]
            lowered = runner.lower(entry, *args)
        audit = audit_fn(fn, args, name=cell_key(cell.name, cell.placement),
                         donate_argnums=donate,
                         expected_donated=expected_donated,
                         expected_fetch_leaves=expected_fetch,
                         lowered=lowered)
        findings.extend(audit.findings)
        rows[cell_key(cell.name, cell.placement)] = audit.budget_row()
    return rows, findings
