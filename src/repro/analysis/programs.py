"""The audited program catalog: every driver x placement x block cell.

One tiny fixed task (4 clients, 32-sample shards) is enough — the audited
invariants (dtypes, callbacks, donation, fetch arity) are shape-independent,
and the tiny config keeps the whole audit under the CI job's time budget.

Cells resolve through the SAME lru-cached factories the drivers use
(``protocol_accept_runner`` / ``splitfed_accept_runner`` / ...), and lower
through ``RoundRunner.lower`` which shares the runner's ``_jitted`` dispatch
cache — the auditor provably sees the program object the drivers run, not a
reconstruction of it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEED = 0
BLOCK_K = 2
SWEEP_SEEDS = (0, 1)


@dataclasses.dataclass
class TinyContext:
    """Deterministically-built inputs shared by every program cell."""
    module: Any
    data: Any
    pcfg: Any
    tm: Any
    theta: Any
    thetas: Any                     # stacked over SWEEP_SEEDS
    x0: Any
    y0: Any
    round_payload: Any
    block_payload: Any              # K = BLOCK_K rounds
    sweep_payload: Any
    sweep_block_payload: Any
    pool_block_payload: Any         # J = 2 lanes x K = BLOCK_K rounds
    pool_val: Any
    pool_active: Any


def build_context() -> TinyContext:
    from repro.adversary import HONEST, resolve_threat_model
    from repro.core import ProtocolConfig, from_cnn
    from repro.core.clustering import make_clusters
    from repro.core.engine import assemble_block, assemble_round
    from repro.data import build_image_task

    data, cfg = build_image_task("mnist", m_clients=4, d_m=32, d_o=16,
                                 n_test=32, seed=SEED)
    module = from_cnn(cfg)
    # eval_every=2 so the block=2 compile cells actually engage round-block
    # fusion instead of degrading to per-round execution
    pcfg = ProtocolConfig(M=4, N=1, T=2, E=1, B=4, lr=0.05, seed=SEED,
                          eval_every=2)
    tm = resolve_threat_model(set(), HONEST, None)

    rng = np.random.default_rng(SEED)
    key = jax.random.PRNGKey(SEED)
    theta = module.init(jax.random.PRNGKey(1))
    x0, y0 = jnp.asarray(data.x0), jnp.asarray(data.y0)

    clusters = make_clusters(rng, pcfg.M, pcfg.R)
    key, round_payload = assemble_round(rng, key, data, clusters, pcfg, tm, 0)
    key, _clusters_k, block_payload = assemble_block(rng, key, data, pcfg,
                                                     tm, 0, BLOCK_K)

    # sweep: S protocol replicas, inputs stacked over the seed axis exactly
    # as run_pigeon_sweep assembles them
    rngs = [np.random.default_rng(s) for s in SWEEP_SEEDS]
    keys, k0s = [], []
    for s in SWEEP_SEEDS:
        k, k0 = jax.random.split(jax.random.PRNGKey(s))
        keys.append(k)
        k0s.append(k0)
    thetas = jax.vmap(module.init)(jnp.stack(k0s))
    xs, ys, avecs, krows = [], [], [], []
    for i in range(len(SWEEP_SEEDS)):
        cs = make_clusters(rngs[i], pcfg.M, pcfg.R)
        keys[i], (x_i, y_i, avec_i, krow) = assemble_round(
            rngs[i], keys[i], data, cs, pcfg, tm, 0)
        xs.append(x_i)
        ys.append(y_i)
        avecs.append(avec_i)
        krows.append(krow)
    avec = jax.tree.map(lambda *ls: jnp.stack(ls), *avecs)
    sweep_payload = (jnp.stack(xs), jnp.stack(ys), avec, jnp.stack(krows))
    # sweep block: K per-round stacked payloads, stacked again on axis 0
    sweep_block_payload = jax.tree.map(
        lambda a: jnp.stack([a] * BLOCK_K), sweep_payload)

    # job pool: J=2 lanes of the block payload (lane-identical inputs are
    # fine for auditing — lane content never shapes the program), thetas
    # reused as the stacked 2-job carry, both lanes active
    pool_block_payload = jax.tree.map(lambda a: jnp.stack([a, a]),
                                      block_payload)
    pool_val = (jnp.stack([x0, x0]), jnp.stack([y0, y0]))
    pool_active = jnp.array([True, True])

    return TinyContext(module=module, data=data, pcfg=pcfg, tm=tm,
                       theta=theta, thetas=thetas, x0=x0, y0=y0,
                       round_payload=round_payload,
                       block_payload=block_payload,
                       sweep_payload=sweep_payload,
                       sweep_block_payload=sweep_block_payload,
                       pool_block_payload=pool_block_payload,
                       pool_val=pool_val,
                       pool_active=pool_active)


@dataclasses.dataclass(frozen=True)
class ProgramCell:
    """One audited program: a runner entry under a placement, or a kernel."""
    name: str                       # e.g. "pigeon/accept@vmap"
    placement: str                  # "vmap" | "sharded" | "kernel"
    realize: Callable[[TinyContext], Tuple[Any, tuple]]
    #        ctx -> (runner_or_None, (fn, args, donate_argnums))


def _pigeon_runner(ctx: TinyContext, placement: str, selection: str = "argmin"):
    from repro.core.runner import protocol_accept_runner
    from repro.selection import resolve_policy
    policy = resolve_policy(selection)
    return protocol_accept_runner(ctx.module, ctx.pcfg.lr, placement, policy,
                                  ctx.pcfg.tamper_check, ctx.pcfg.tamper_tol,
                                  quant=ctx.pcfg.comm.quant)


def _splitfed_runner(ctx: TinyContext, placement: str):
    from repro.core.engine import splitfed_accept_runner
    from repro.selection import resolve_policy
    return splitfed_accept_runner(ctx.module, ctx.pcfg.lr, placement,
                                  resolve_policy("argmin"),
                                  quant=ctx.pcfg.comm.quant)


def _sweep_runner(ctx: TinyContext, placement: str):
    from repro.core.runner import protocol_runner
    from repro.selection import resolve_policy
    policy = resolve_policy("argmin")
    return protocol_runner(ctx.module, ctx.pcfg.lr, placement,
                           policy.needs_message_stats, policy,
                           ctx.pcfg.comm.quant)


def _entry_cell(runner_of, entry: str, args_of):
    def realize(ctx: TinyContext):
        r = runner_of(ctx)
        return r, (r.audit_body(entry), args_of(ctx), r.donated_argnums(entry))
    return realize


def _quant_cell(stats: bool):
    def realize(ctx: TinyContext):
        from repro.kernels.quant_exchange import (quant_dequant,
                                                  quant_dequant_stats)
        x = jnp.asarray(np.linspace(-3, 3, 32 * 16,
                                    dtype=np.float32).reshape(32, 16))
        if stats:
            fn = lambda v: quant_dequant_stats(v, "int8", interpret=True)
        else:
            fn = lambda v: quant_dequant(v, "int8", interpret=True)
        return None, (fn, (x,), ())
    return realize


def _round_args(ctx):
    return (ctx.theta, ctx.round_payload, (ctx.x0, ctx.y0))


def _block_args(ctx):
    return (ctx.theta, ctx.block_payload, (ctx.x0, ctx.y0))


def _sweep_args(ctx):
    return (ctx.thetas, ctx.sweep_payload, (ctx.x0, ctx.y0))


def _sweep_block_args(ctx):
    return (ctx.thetas, ctx.sweep_block_payload, (ctx.x0, ctx.y0))


def _pool_block_args(ctx):
    return (ctx.thetas, ctx.pool_block_payload, ctx.pool_val,
            ctx.pool_active)


CELLS: List[ProgramCell] = [
    # pigeon accept cascade: the default batched driver path
    ProgramCell("pigeon/accept@vmap", "vmap",
                _entry_cell(lambda c: _pigeon_runner(c, "vmap"),
                            "accept", _round_args)),
    ProgramCell("pigeon/accept@sharded", "sharded",
                _entry_cell(lambda c: _pigeon_runner(c, "sharded"),
                            "accept", _round_args)),
    ProgramCell("pigeon/accept_block@vmap", "vmap",
                _entry_cell(lambda c: _pigeon_runner(c, "vmap"),
                            "accept_block", _block_args)),
    ProgramCell("pigeon/accept_block@sharded", "sharded",
                _entry_cell(lambda c: _pigeon_runner(c, "sharded"),
                            "accept_block", _block_args)),
    # representative non-argmin policy (message-stats lane active)
    ProgramCell("pigeon/accept@vmap+loss_plus_distance", "vmap",
                _entry_cell(lambda c: _pigeon_runner(
                    c, "vmap", "loss_plus_distance"),
                    "accept", _round_args)),
    # launch-layer full round (selection + winner broadcast in-program)
    ProgramCell("pigeon/round@vmap", "vmap",
                _entry_cell(lambda c: _pigeon_runner(c, "vmap"),
                            "round", _round_args)),
    ProgramCell("pigeon/round@sharded", "sharded",
                _entry_cell(lambda c: _pigeon_runner(c, "sharded"),
                            "round", _round_args)),
    # splitfed FedAvg + policy cascade
    ProgramCell("splitfed/accept@vmap", "vmap",
                _entry_cell(lambda c: _splitfed_runner(c, "vmap"),
                            "accept", _round_args)),
    ProgramCell("splitfed/accept_block@vmap", "vmap",
                _entry_cell(lambda c: _splitfed_runner(c, "vmap"),
                            "accept_block", _block_args)),
    # job pool: J jobs megabatched onto the accept_block scan (one stacked
    # (J, K, 2R+3) fetch; theta_J carry donated)
    ProgramCell("pigeon/pool_accept_block@vmap", "vmap",
                _entry_cell(lambda c: _pigeon_runner(c, "vmap"),
                            "pool_accept_block", _pool_block_args)),
    ProgramCell("pigeon/pool_accept_block@sharded", "sharded",
                _entry_cell(lambda c: _pigeon_runner(c, "sharded"),
                            "pool_accept_block", _pool_block_args)),
    # multi-seed sweep
    ProgramCell("sweep/sweep@vmap", "vmap",
                _entry_cell(lambda c: _sweep_runner(c, "vmap"),
                            "sweep", _sweep_args)),
    ProgramCell("sweep/sweep_block@vmap", "vmap",
                _entry_cell(lambda c: _sweep_runner(c, "vmap"),
                            "sweep_block", _sweep_block_args)),
    ProgramCell("sweep/sweep@sharded", "sharded",
                _entry_cell(lambda c: _sweep_runner(c, "sharded"),
                            "sweep", _sweep_args)),
    # quant-exchange kernel (interpret mode on CPU; same program structure)
    ProgramCell("kernels/quant_dequant@int8", "kernel",
                _quant_cell(stats=False)),
    ProgramCell("kernels/quant_dequant_stats@int8", "kernel",
                _quant_cell(stats=True)),
]


def expected_counts(fn: Callable, args: tuple,
                    donate_argnums: Tuple[int, ...]) -> Tuple[int, int]:
    """(expected_donated, expected_fetch_leaves) for one cell: the donated
    carry must alias leaf-for-leaf, and everything else the program returns
    is the stacked fetch."""
    donated = sum(len(jax.tree.leaves(args[i])) for i in donate_argnums)
    out = jax.eval_shape(fn, *args)
    return donated, len(jax.tree.leaves(out)) - donated


def select_cells(placements: Tuple[str, ...] = ("vmap", "kernel"),
                 names: Optional[Tuple[str, ...]] = None) -> List[ProgramCell]:
    cells = [c for c in CELLS if c.placement in placements]
    if names:
        cells = [c for c in cells if c.name in names]
    return cells
