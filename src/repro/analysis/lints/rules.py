"""The repo-specific lint rules.

Five rule classes, each encoding one bug class this codebase has actually
hit or explicitly guards against:

- ``prng-key-reuse``      — a jax.random key consumed by two calls without an
                            interleaving ``split``/``fold_in`` rebind (the
                            on-stream-resume bug class from PR 4).
- ``hidden-host-sync``    — ``float()`` / ``.item()`` / ``np.asarray`` on
                            device values inside ``core/engine.py`` /
                            ``core/runner.py``; everything outside the
                            whitelisted stacked-fetch sites breaks the
                            one-fetch-per-round contract.
- ``wall-clock``          — ``time.time()`` anywhere but
                            ``telemetry/provenance.py``; timing must use the
                            monotonic ``perf_counter`` family.
- ``unseeded-np-random``  — module-level ``np.random.*`` draws off the global
                            (unseeded) numpy state.
- ``mutable-default-arg`` — the classic shared-mutable-default trap.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional, Set, Tuple

from ..findings import Finding
from .base import (LintContext, LintRule, dotted_name, expr_calls,
                   function_scopes, import_aliases, resolve_call,
                   assignment_targets, scope_events, FunctionNode)


# ---------------------------------------------------------------------------
# prng-key-reuse
# ---------------------------------------------------------------------------

# jax.random calls whose first positional argument is a key they CONSUME.
# (split / fold_in consume too — but their result is normally rebound, which
# refreshes the name.)
_KEY_NONCONSUMING = {"PRNGKey", "key", "key_data", "wrap_key_data",
                     "default_prng_impl", "key_impl", "clone"}


class PRNGKeyReuse(LintRule):
    id = "prng-key-reuse"
    severity = "error"
    description = ("jax.random key consumed twice without an interleaving "
                   "split/fold_in rebind")

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        aliases = import_aliases(ctx.tree)
        for _scope, body in function_scopes(ctx.tree):
            consumed: Set[str] = set()
            # branch stack: (state saved at branch entry, finished branches)
            stack: List[Tuple[Set[str], List[Set[str]]]] = []
            reported: Set[int] = set()
            for kind, payload in scope_events(body):
                if kind == "push":
                    stack.append((set(consumed), []))
                elif kind == "alt":
                    saved, acc = stack[-1]
                    acc.append(consumed)
                    consumed = set(saved)
                elif kind == "pop":
                    _saved, acc = stack.pop()
                    acc.append(consumed)
                    consumed = set().union(*acc)
                elif kind == "bind":
                    consumed -= payload  # rebind refreshes the name
                elif kind == "call":
                    call = payload
                    full = resolve_call(call, aliases)
                    if not full or not full.startswith("jax.random."):
                        continue
                    fn = full.rsplit(".", 1)[1]
                    if fn in _KEY_NONCONSUMING or not call.args:
                        continue
                    arg = call.args[0]
                    if not isinstance(arg, ast.Name):
                        continue
                    name = arg.id
                    if name in consumed:
                        if id(call) not in reported:
                            reported.add(id(call))
                            yield self.finding(
                                ctx, call,
                                f"key '{name}' already consumed by an earlier "
                                f"jax.random call; split/fold_in before "
                                f"reusing it (jax.random.{fn})")
                    else:
                        consumed.add(name)


# ---------------------------------------------------------------------------
# hidden-host-sync
# ---------------------------------------------------------------------------

_SYNC_FILES = ("src/repro/core/engine.py", "src/repro/core/runner.py")

# call targets whose results are host values regardless of their arguments
_HOST_MODULE_PREFIX = ("numpy.", "os.", "time.", "math.")
_HOST_BUILTINS = {"range", "len", "int", "str", "bool", "list", "tuple",
                  "dict", "sorted", "enumerate", "zip", "min", "max", "sum",
                  "abs", "isinstance", "getattr", "hasattr"}
# repo-specific: results that are host values by construction.  jax.devices()
# returns Device handles (mesh building), and the unpack_* helpers only ever
# see the already-fetched stacked round vector — THE whitelisted fetch path.
_HOST_CALL_SUFFIX = {"devices", "local_devices"}          # jax.devices etc.
_HOST_WHITELIST_FNS = {"unpack_fetch", "unpack_block_fetch",
                       "evaluate", "evaluate_sweep"}


class HiddenHostSync(LintRule):
    id = "hidden-host-sync"
    severity = "error"
    description = ("float()/.item()/np.asarray on a device value in the "
                   "round engine outside whitelisted stacked-fetch sites")

    def applies(self, relpath: str) -> bool:
        return relpath in _SYNC_FILES

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        aliases = import_aliases(ctx.tree)
        for _scope, body in function_scopes(ctx.tree):
            host: Set[str] = set()
            found: List[Finding] = []

            def is_host(e: Optional[ast.AST]) -> bool:
                """Conservative 'definitely a host value' — False means the
                expression may hold a live device array."""
                if e is None or isinstance(e, ast.Constant):
                    return True
                if isinstance(e, ast.Name):
                    return e.id in host
                if isinstance(e, ast.Attribute):
                    base = dotted_name(e)
                    if base is not None:
                        head = base.split(".")[0]
                        mod = aliases.get(head, head)
                        if mod in ("numpy", "os", "time", "math"):
                            return True
                    return is_host(e.value)
                if isinstance(e, (ast.Subscript, ast.Starred)):
                    return is_host(e.value)
                if isinstance(e, (ast.BinOp, ast.BoolOp, ast.Compare,
                                  ast.UnaryOp, ast.IfExp, ast.Tuple, ast.List,
                                  ast.Set, ast.Dict, ast.JoinedStr,
                                  ast.FormattedValue, ast.Slice)):
                    return all(is_host(c) for c in ast.iter_child_nodes(e)
                               if not isinstance(c, (ast.operator, ast.boolop,
                                                     ast.cmpop, ast.unaryop,
                                                     ast.expr_context)))
                if isinstance(e, ast.Call):
                    return call_result_is_host(e)
                if isinstance(e, (ast.ListComp, ast.SetComp, ast.DictComp,
                                  ast.GeneratorExp)):
                    return all(is_host(g.iter) for g in e.generators)
                return False

            def call_result_is_host(call: ast.Call) -> bool:
                # results of fetches/materializations are host values (the
                # fetch itself is reported separately by ``check``)
                if (isinstance(call.func, ast.Attribute)
                        and call.func.attr in ("item", "tolist")):
                    return True
                full = resolve_call(call, aliases)
                if full is None:
                    return False
                tail = full.rsplit(".", 1)[-1]
                if full.startswith("jax.") and tail in _HOST_CALL_SUFFIX:
                    return True
                if tail in _HOST_WHITELIST_FNS:
                    return True
                return (full == "float" or full in _HOST_BUILTINS
                        or full.startswith(_HOST_MODULE_PREFIX))

            def check(call: ast.Call) -> None:
                """Emit findings for the three sync idioms on device args."""
                full = resolve_call(call, aliases)
                if (isinstance(call.func, ast.Attribute)
                        and call.func.attr == "item" and not call.args):
                    if not is_host(call.func.value):
                        found.append(self.finding(
                            ctx, call,
                            ".item() on a device value forces a per-element "
                            "host sync; go through the stacked fetch"))
                    return
                args_host = all(is_host(a) for a in call.args)
                if full in ("numpy.asarray", "numpy.array") and not args_host:
                    found.append(self.finding(
                        ctx, call,
                        f"{full}() on a device value is a device->host "
                        f"transfer; whitelist intended fetch sites in the "
                        f"baseline"))
                elif full == "float" and not args_host:
                    found.append(self.finding(
                        ctx, call,
                        "float() on a device value blocks on a host sync; "
                        "fetch through the stacked round vector instead"))

            # The flat event stream does not tie calls to their binding
            # statement, so this rule walks statements directly, threading
            # the host-name set through assignments.
            self._walk(body, host, is_host, check)
            for f in found:
                yield f

    def _walk(self, body, host, is_host, check) -> None:
        """Statement-order walk maintaining the host-name set; ``check``
        emits findings as a side effect."""
        for stmt in body:
            if isinstance(stmt, FunctionNode) or isinstance(stmt, ast.ClassDef):
                continue
            # comprehension variables iterate host values -> host for the
            # duration of this statement ([float(v) for v in fetched])
            tmp: Set[str] = set()
            for node in ast.walk(stmt):
                if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                     ast.GeneratorExp)):
                    for g in node.generators:
                        if is_host(g.iter):
                            tmp |= _target_names(g.target)
            tmp -= host
            host |= tmp
            if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = getattr(stmt, "value", None)
                for c in expr_calls(value):
                    check(c)
                if is_host(value):
                    host |= assignment_targets(stmt)
                else:
                    host -= assignment_targets(stmt)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                for c in expr_calls(stmt.iter):
                    check(c)
                if is_host(stmt.iter):
                    host |= assignment_targets(stmt)
                else:
                    host -= assignment_targets(stmt)
                self._walk(stmt.body, host, is_host, check)
                self._walk(stmt.orelse, host, is_host, check)
            elif isinstance(stmt, ast.While):
                for c in expr_calls(stmt.test):
                    check(c)
                self._walk(stmt.body, host, is_host, check)
            elif isinstance(stmt, ast.If):
                for c in expr_calls(stmt.test):
                    check(c)
                self._walk(stmt.body, host, is_host, check)
                self._walk(stmt.orelse, host, is_host, check)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    for c in expr_calls(item.context_expr):
                        check(c)
                self._walk(stmt.body, host, is_host, check)
            elif isinstance(stmt, ast.Try):
                self._walk(stmt.body, host, is_host, check)
                for h in stmt.handlers:
                    self._walk(h.body, host, is_host, check)
                self._walk(stmt.orelse, host, is_host, check)
                self._walk(stmt.finalbody, host, is_host, check)
            else:
                for c in expr_calls(stmt):
                    check(c)
            host -= tmp


def _target_names(t: ast.AST) -> Set[str]:
    out: Set[str] = set()
    if isinstance(t, ast.Name):
        out.add(t.id)
    elif isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            out |= _target_names(e)
    elif isinstance(t, ast.Starred):
        out |= _target_names(t.value)
    return out


# ---------------------------------------------------------------------------
# wall-clock
# ---------------------------------------------------------------------------

class WallClock(LintRule):
    id = "wall-clock"
    severity = "error"
    description = "time.time() outside telemetry/provenance.py"

    EXEMPT = ("src/repro/telemetry/provenance.py",)

    def applies(self, relpath: str) -> bool:
        return relpath not in self.EXEMPT

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        aliases = import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                full = resolve_call(node, aliases)
                if full in ("time.time", "time.time_ns"):
                    yield self.finding(
                        ctx, node,
                        "time.time() steps under NTP; use time.perf_counter "
                        "(timing) or telemetry.provenance (wall-clock stamps)")


# ---------------------------------------------------------------------------
# unseeded-np-random
# ---------------------------------------------------------------------------

class UnseededNpRandom(LintRule):
    id = "unseeded-np-random"
    severity = "error"
    description = "module-level np.random.* draw off the global numpy state"

    # constructors / seeding calls that are fine at module level
    OK = {"default_rng", "Generator", "RandomState", "seed", "SeedSequence",
          "PCG64", "Philox", "MT19937", "SFC64", "BitGenerator"}

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        aliases = import_aliases(ctx.tree)
        module_body = list(getattr(ctx.tree, "body", []))
        for kind, payload in scope_events(module_body):
            if kind != "call":
                continue
            full = resolve_call(payload, aliases)
            if not full or not full.startswith("numpy.random."):
                continue
            fn = full.split(".")[-1]
            if fn in self.OK:
                continue
            yield self.finding(
                ctx, payload,
                f"module-level np.random.{fn}() draws from the global "
                f"unseeded state; thread an np.random.default_rng(seed) "
                f"Generator instead")


# ---------------------------------------------------------------------------
# mutable-default-arg
# ---------------------------------------------------------------------------

class MutableDefaultArg(LintRule):
    id = "mutable-default-arg"
    severity = "error"
    description = "mutable default argument shared across calls"

    _MUTABLE_CALLS = {"list", "dict", "set", "bytearray",
                      "collections.defaultdict", "collections.OrderedDict"}

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        aliases = import_aliases(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                bad = isinstance(d, (ast.List, ast.Dict, ast.Set))
                if isinstance(d, ast.Call):
                    full = resolve_call(d, aliases)
                    bad = full in self._MUTABLE_CALLS
                if bad:
                    name = getattr(node, "name", "<lambda>")
                    yield self.finding(
                        ctx, d,
                        f"mutable default argument in '{name}' is shared "
                        f"across calls; default to None and construct inside")


LINT_RULES: List[LintRule] = [
    PRNGKeyReuse(),
    HiddenHostSync(),
    WallClock(),
    UnseededNpRandom(),
    MutableDefaultArg(),
]
