"""Lint infrastructure: per-file AST context and the rule protocol.

Rules are small classes with an ``id``, a ``severity`` and a ``run(ctx)``
generator of raw findings; the registry (``lints/__init__.py``) walks the
source tree once, parses each file once and hands the shared
:class:`LintContext` to every applicable rule.  Helpers here do the common
AST chores: resolving dotted call names through import aliases, walking
statements in execution order and iterating function scopes.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..findings import Finding, make_finding


@dataclasses.dataclass
class LintContext:
    """One parsed source file, shared by every rule."""
    path: str                   # absolute
    relpath: str                # repo-relative, forward slashes
    source: str
    tree: ast.AST
    lines: List[str]

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


class LintRule:
    """Base rule.  Subclasses set ``id``/``severity``/``description`` and
    implement ``run``; ``applies`` scopes a rule to specific files (default:
    every Python file under the linted roots)."""
    id: str = ""
    severity: str = "error"
    description: str = ""

    def applies(self, relpath: str) -> bool:
        return True

    def run(self, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: LintContext, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        return make_finding(self.id, self.severity, ctx.relpath, line,
                            message, context=ctx.line_text(line))


def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.random.seed' for Attribute chains rooted at a Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Local name -> fully qualified module/attribute for every import in
    the file (``import jax.random as jr`` -> {'jr': 'jax.random'};
    ``from jax import random`` -> {'random': 'jax.random'};
    ``from jax.random import split`` -> {'split': 'jax.random.split'})."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def resolve_call(node: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    """Fully qualified dotted name of a call target, through import
    aliases: ``jr.split(k)`` -> 'jax.random.split'."""
    name = dotted_name(node.func)
    if name is None:
        return None
    head, _, tail = name.partition(".")
    full_head = aliases.get(head, head)
    return f"{full_head}.{tail}" if tail else full_head


def assignment_targets(stmt: ast.stmt) -> Set[str]:
    """Plain names (re)bound by a statement, tuple targets included."""
    out: Set[str] = set()

    def collect(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                collect(e)
        elif isinstance(t, ast.Starred):
            collect(t.value)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            collect(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For,
                           ast.AsyncFor)):
        collect(stmt.target)
    elif isinstance(stmt, (ast.With, ast.AsyncWith)):
        for item in stmt.items:
            if item.optional_vars is not None:
                collect(item.optional_vars)
    return out


FunctionNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def function_scopes(tree: ast.AST) -> Iterator[Tuple[ast.AST, List[ast.stmt]]]:
    """Every function-like scope in the file (module included), with its
    statement list.  Lambdas yield their body expression wrapped in an
    ``ast.Expr`` so scope walkers see a uniform statement list."""
    yield tree, list(getattr(tree, "body", []))
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, list(node.body)
        elif isinstance(node, ast.Lambda):
            expr = ast.Expr(value=node.body)
            ast.copy_location(expr, node.body)
            yield node, [expr]


def expr_calls(node: Optional[ast.AST]) -> Iterator[ast.Call]:
    """Call nodes inside one expression in evaluation (post-)order — inner
    calls before the call consuming their result — without descending into
    nested function/lambda scopes (those are separate scopes)."""
    if node is None:
        return
    if isinstance(node, FunctionNode):
        return
    for child in ast.iter_child_nodes(node):
        yield from expr_calls(child)
    if isinstance(node, ast.Call):
        yield node


def scope_events(body: List[ast.stmt]) -> Iterator[Tuple[str, object]]:
    """A scope's calls and name bindings as one linear event stream:
    ``('call', Call)`` / ``('bind', set_of_names)``, in approximate
    execution order.  Compound statements contribute their header
    expressions, then their bodies; loop bodies are walked TWICE — the
    second pass models the next iteration, so state consumed in a loop body
    without an interleaving rebind is caught as cross-iteration reuse.
    Nested function/lambda scopes are skipped (they are their own scopes)."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            for c in expr_calls(stmt.iter):
                yield "call", c
            for _ in range(2):
                yield "bind", assignment_targets(stmt)
                yield from scope_events(stmt.body)
            yield from scope_events(stmt.orelse)
        elif isinstance(stmt, ast.While):
            for _ in range(2):
                for c in expr_calls(stmt.test):
                    yield "call", c
                yield from scope_events(stmt.body)
            yield from scope_events(stmt.orelse)
        elif isinstance(stmt, ast.If):
            for c in expr_calls(stmt.test):
                yield "call", c
            yield "push", None
            yield from scope_events(stmt.body)
            yield "alt", None
            yield from scope_events(stmt.orelse)
            yield "pop", None
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                for c in expr_calls(item.context_expr):
                    yield "call", c
            yield "bind", assignment_targets(stmt)
            yield from scope_events(stmt.body)
        elif isinstance(stmt, ast.Try):
            yield from scope_events(stmt.body)
            for h in stmt.handlers:
                yield "push", None
                yield from scope_events(h.body)
                yield "alt", None
                yield "pop", None
            yield from scope_events(stmt.orelse)
            yield from scope_events(stmt.finalbody)
        elif isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            for c in expr_calls(getattr(stmt, "value", None)):
                yield "call", c
            yield "bind", assignment_targets(stmt)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            for c in expr_calls(stmt.value):
                yield "call", c
        else:
            for c in expr_calls(stmt):
                yield "call", c
