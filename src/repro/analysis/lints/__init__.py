"""Lint layer: walk the source tree once, run every applicable rule.

``run_lints(root)`` returns the full finding list (pre-baseline); the CLI
layers the suppression baseline on top via :class:`repro.analysis.findings.
Report`.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, Iterator, List, Optional

from ..findings import Finding, assign_fingerprints, make_finding
from .base import LintContext
from .rules import LINT_RULES  # noqa: F401  (public registry)

# Directories linted, relative to the repo root.  Tests and examples are out
# of scope: they intentionally poke at device values and ad-hoc clocks.
LINT_ROOTS = ("src/repro", "scripts", "benchmarks")


def iter_python_files(root: str,
                      roots: Iterable[str] = LINT_ROOTS) -> Iterator[str]:
    """Absolute paths of every linted .py file, deterministic order."""
    for rel in roots:
        base = os.path.join(root, rel)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in ("__pycache__", ".git"))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_file(root: str, path: str) -> List[Finding]:
    relpath = os.path.relpath(path, root).replace(os.sep, "/")
    try:
        with open(path, "r", encoding="utf-8") as f:
            source = f.read()
        tree = ast.parse(source, filename=path)
    except (OSError, SyntaxError) as e:
        return [make_finding("parse-error", "error", relpath,
                             getattr(e, "lineno", 0) or 0,
                             f"could not parse: {e}")]
    ctx = LintContext(path=path, relpath=relpath, source=source, tree=tree,
                      lines=source.splitlines())
    out: List[Finding] = []
    for rule in LINT_RULES:
        if rule.applies(relpath):
            out.extend(rule.run(ctx))
    return out


def run_lints(root: str,
              files: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint the tree under ``root`` (or just ``files``) and return findings
    with stable per-file fingerprints, sorted by location."""
    paths = list(files) if files is not None else list(iter_python_files(root))
    findings: List[Finding] = []
    for path in paths:
        findings.extend(lint_file(root, path))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.context))
    return assign_fingerprints(findings)
