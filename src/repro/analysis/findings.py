"""Findings engine: the common currency of both analysis layers.

A :class:`Finding` is one violation — a lint hit at a file:line, a jaxpr
invariant break inside a lowered round program, or a budget mismatch against
a checked-in baseline.  Findings are *stable*: the fingerprint hashes the
rule, the file and the normalized source context (NOT the line number), so
unrelated edits that shift lines do not churn the baseline file.

The baseline (``analysis/lint_baseline.json``) is the suppression mechanism
for *intentional* findings — e.g. the drivers' whitelisted stacked-fetch
``np.asarray`` sites.  Every suppression MUST carry a one-line
``justification``; a suppression without one is itself reported as a
finding, so the "new suppressions need a reason" contributor rule is
machine-enforced rather than review-enforced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, Iterable, List, Optional

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analysis violation.

    ``path`` is repo-relative with forward slashes; ``context`` is the
    normalized source line (lints) or a program/cell identifier (audits);
    ``fingerprint`` identifies the finding across line shifts."""
    rule: str
    severity: str
    path: str
    line: int
    message: str
    context: str = ""
    fingerprint: str = ""

    def located(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.rule}] {self.message}"

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def fingerprint(rule: str, path: str, context: str, index: int = 0) -> str:
    """Stable identity of a finding: rule + file + normalized context +
    occurrence index (disambiguates identical lines in one file)."""
    norm = " ".join(context.split())
    h = hashlib.sha1(f"{rule}|{path}|{norm}|{index}".encode()).hexdigest()
    return h[:16]


def make_finding(rule: str, severity: str, path: str, line: int, message: str,
                 context: str = "", index: int = 0) -> Finding:
    return Finding(rule=rule, severity=severity, path=path, line=line,
                   message=message, context=context,
                   fingerprint=fingerprint(rule, path, context, index))


def assign_fingerprints(findings: Iterable[Finding]) -> List[Finding]:
    """Re-derive fingerprints with per-(rule, path, context) occurrence
    indices, in input order — call once after collecting a file's findings
    so duplicate source lines stay distinguishable."""
    seen: Dict[str, int] = {}
    out = []
    for f in findings:
        key = f"{f.rule}|{f.path}|{' '.join(f.context.split())}"
        idx = seen.get(key, 0)
        seen[key] = idx + 1
        out.append(dataclasses.replace(
            f, fingerprint=fingerprint(f.rule, f.path, f.context, idx)))
    return out


# ---------------------------------------------------------------------------
# baseline suppressions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Baseline:
    """The checked-in suppression list.  ``entries`` maps fingerprint ->
    {rule, file, justification, context}."""
    path: str
    entries: Dict[str, Dict[str, Any]] = dataclasses.field(default_factory=dict)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(path=path)
        with open(path, "r", encoding="utf-8") as f:
            raw = json.load(f)
        entries = {e["fingerprint"]: e for e in raw.get("suppressions", [])}
        return cls(path=path, entries=entries)

    def save(self) -> None:
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        rows = sorted(self.entries.values(),
                      key=lambda e: (e.get("file", ""), e.get("rule", ""),
                                     e.get("context", "")))
        with open(self.path, "w", encoding="utf-8") as f:
            json.dump({"suppressions": rows}, f, indent=2, sort_keys=True)
            f.write("\n")

    def suppresses(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def unjustified(self) -> List[Dict[str, Any]]:
        """Suppressions missing the mandatory one-line justification."""
        return [e for e in self.entries.values()
                if not str(e.get("justification", "")).strip()]

    def stale(self, findings: Iterable[Finding]) -> List[Dict[str, Any]]:
        """Suppressions whose finding no longer exists (safe to delete)."""
        live = {f.fingerprint for f in findings}
        return [e for fp, e in sorted(self.entries.items()) if fp not in live]

    def add(self, finding: Finding, justification: str) -> None:
        self.entries[finding.fingerprint] = {
            "fingerprint": finding.fingerprint, "rule": finding.rule,
            "file": finding.path, "context": " ".join(finding.context.split()),
            "justification": justification,
        }


@dataclasses.dataclass
class Report:
    """A full analysis run: raw findings + the baseline they were filtered
    against.  ``open_findings`` is what gates CI."""
    findings: List[Finding] = dataclasses.field(default_factory=list)
    baseline: Optional[Baseline] = None
    notes: List[str] = dataclasses.field(default_factory=list)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    @property
    def open_findings(self) -> List[Finding]:
        if self.baseline is None:
            return list(self.findings)
        out = [f for f in self.findings if not self.baseline.suppresses(f)]
        for e in self.baseline.unjustified():
            out.append(make_finding(
                "unjustified-suppression", "error",
                os.path.basename(self.baseline.path), 0,
                f"suppression {e['fingerprint']} ({e.get('rule')}) has no "
                f"justification — add a one-line reason",
                context=e["fingerprint"]))
        return out

    def to_dict(self) -> Dict[str, Any]:
        suppressed = ([] if self.baseline is None else
                      [f.to_dict() for f in self.findings
                       if self.baseline.suppresses(f)])
        return {
            "open": [f.to_dict() for f in self.open_findings],
            "suppressed": suppressed,
            "stale_suppressions": ([] if self.baseline is None
                                   else self.baseline.stale(self.findings)),
            "notes": list(self.notes),
        }


def repo_root(explicit: Optional[str] = None) -> str:
    """The working tree the analyzer audits: ``explicit`` when given, else
    the checkout containing this package (src/repro/analysis -> repo)."""
    if explicit:
        return os.path.abspath(explicit)
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))
