"""Layer 1: static invariant checks on lowered round programs.

Four invariants, each of which the runtime equivalence tests can only
witness indirectly, are proven on the program text itself:

1. **No float64.**  The attack/selection arithmetic is an f32 lane; a bare
   Python literal in a ``jnp.where`` promotes to a weak f64 scalar the
   moment anyone enables x64.  The auditor retraces every entry body under
   ``jax.experimental.enable_x64()`` — f32 example inputs stay f32, so any
   float64 dtype in the retraced jaxpr is a latent weak-type leak.
2. **No host callbacks.**  ``pure_callback`` / ``io_callback`` /
   ``debug_callback`` inside a device round serializes the round on host
   round-trips; the jaxpr must not contain the callback primitives and the
   compiled HLO must not contain callback custom-calls or channel ops
   (:func:`repro.launch.hlo_analysis.host_transfer_counts`).
3. **Donation applied.**  ``donate_argnums`` is intent; the proof is the
   lowered module's ``tf.aliasing_output`` attributes and the compiled
   executable's ``input_output_alias`` header.  Each donated entry must
   alias exactly one output per theta-carry leaf.
4. **One stacked fetch.**  The only non-aliased outputs of a device round
   are the stacked fetch leaves; their count is pinned per entry
   (accept -> 1, round -> 2, sweep -> 3, ...).

Every check returns :class:`~repro.analysis.findings.Finding` objects so
the CLI/CI layer treats program violations and lint hits uniformly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from ..launch.hlo_analysis import host_transfer_counts
from .findings import Finding, make_finding

# jaxpr primitives that re-enter the host mid-program
CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback",
                       "debug_print", "callback")

_ALIAS_PAIR_RE = re.compile(r"\{(\d+)\}:\s*\((\d+),")


def _balanced_region(text: str, key: str) -> Optional[str]:
    """Contents of the brace block opened by ``key`` (which ends in ``{``),
    matched by brace depth — the block nests shape braces like ``{0}``."""
    i = text.find(key)
    if i < 0:
        return None
    start = i + len(key)
    depth = 1
    for j in range(start, len(text)):
        c = text[j]
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                return text[start:j]
    return None


def iter_eqns(jaxpr):
    """Every equation of a (closed) jaxpr, descending into sub-jaxprs held
    in equation params (scan/while/cond bodies, custom_vjp calls, ...)."""
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jx.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_eqns(inner)
                elif hasattr(sub, "eqns"):
                    yield from iter_eqns(sub)


def find_dtypes(jaxpr, bad: Sequence[str] = ("float64",)) -> List[Tuple[str, str]]:
    """(primitive, dtype) pairs for every eqn touching a forbidden dtype."""
    bad = tuple(bad)
    hits: List[Tuple[str, str]] = []
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            dtype = getattr(aval, "dtype", None)
            if dtype is not None and str(dtype) in bad:
                hits.append((eqn.primitive.name, str(dtype)))
    return hits


def find_callbacks(jaxpr) -> List[str]:
    """Names of host-callback primitives appearing anywhere in the jaxpr."""
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in CALLBACK_PRIMITIVES]


def lowered_alias_count(lowered_text: str) -> int:
    """Donated-input markers in the lowered StableHLO (pre-compile intent).
    Single-device lowerings pin the pairing as ``tf.aliasing_output``;
    multi-device (sharded) lowerings mark ``jax.buffer_donor`` and leave the
    pairing to XLA — both prove the donation survived lowering."""
    return (lowered_text.count("tf.aliasing_output")
            + lowered_text.count("jax.buffer_donor"))


def compiled_alias_pairs(compiled_text: str) -> List[Tuple[int, int]]:
    """(output_index, input_index) pairs from the compiled executable's
    ``input_output_alias`` header — donation as actually applied."""
    body = _balanced_region(compiled_text, "input_output_alias={")
    if body is None:
        return []
    return [(int(o), int(i)) for o, i in _ALIAS_PAIR_RE.findall(body)]


def entry_output_arity(compiled_text: str) -> Optional[int]:
    """Number of entry outputs, from the entry_computation_layout header."""
    m = re.search(r"entry_computation_layout=.*?->\s*(\([^)]*\)|[^,]+?)\}",
                  compiled_text, re.DOTALL)
    if not m:
        return None
    body = m.group(1)
    if body.startswith("("):
        inner = body[1:-1] if body.endswith(")") else body[1:]
        if not inner.strip():
            return 0
        # arity = top-level comma count + 1 (shapes contain bracketed commas)
        depth, count = 0, 1
        for c in inner:
            if c in "([{":
                depth += 1
            elif c in ")]}":
                depth -= 1
            elif c == "," and depth == 0:
                count += 1
        return count
    return 1


@dataclasses.dataclass
class ProgramAudit:
    """Everything the auditor measured about one program cell."""
    name: str
    findings: List[Finding]
    eqns: int = 0
    donated_inputs: int = 0
    aliased_outputs: int = 0
    outputs: int = 0
    fetch_leaves: int = 0
    transfers: Dict[str, int] = dataclasses.field(default_factory=dict)

    def budget_row(self) -> Dict[str, Any]:
        """The numbers pinned in ``analysis/budgets/programs.json``."""
        return {
            "eqns": self.eqns,
            "donated_inputs": self.donated_inputs,
            "aliased_outputs": self.aliased_outputs,
            "outputs": self.outputs,
            "fetch_leaves": self.fetch_leaves,
            "outfeed": self.transfers.get("outfeed", 0),
            "infeed": self.transfers.get("infeed", 0),
            "send": self.transfers.get("send", 0),
            "recv": self.transfers.get("recv", 0),
            "host_callback": self.transfers.get("host_callback", 0),
            "custom_call": self.transfers.get("custom_call", 0),
        }


def audit_fn(fn: Callable, args: tuple, *, name: str,
             donate_argnums: Tuple[int, ...] = (),
             expected_donated: int = 0,
             expected_fetch_leaves: Optional[int] = None,
             x64_retrace: bool = True,
             compile_program: bool = True,
             lowered=None) -> ProgramAudit:
    """Audit one jittable callable against the four invariants.

    ``fn`` is the *un-jitted* body (e.g. ``RoundRunner.audit_body(which)``);
    ``expected_donated`` is the number of carry leaves that must alias
    (0 for non-donated entries); ``expected_fetch_leaves`` pins the
    non-aliased output count when given.  Pass ``lowered`` (e.g. from
    ``RoundRunner.lower``) to audit the driver's own program object instead
    of re-lowering a fresh jit of ``fn``.
    """
    findings: List[Finding] = []
    path = f"program:{name}"

    jx = jax.make_jaxpr(fn)(*args)
    eqns = sum(1 for _ in iter_eqns(jx))

    for prim, dtype in find_dtypes(jx):
        findings.append(make_finding(
            "f64-in-program", "error", path, 0,
            f"{dtype} value flows through '{prim}' in the traced program",
            context=f"{name}:{prim}"))
    if x64_retrace:
        with jax.experimental.enable_x64():
            jx64 = jax.make_jaxpr(fn)(*args)
        for prim, dtype in find_dtypes(jx64):
            findings.append(make_finding(
                "f64-in-program", "error", path, 0,
                f"weak-type promotion: '{prim}' becomes {dtype} under x64 "
                f"(pin the literal to jnp.float32)",
                context=f"{name}:x64:{prim}"))

    for prim in find_callbacks(jx):
        findings.append(make_finding(
            "host-callback-in-program", "error", path, 0,
            f"host callback primitive '{prim}' inside the device program",
            context=f"{name}:{prim}"))

    if lowered is None:
        lowered = jax.jit(fn, donate_argnums=donate_argnums).lower(*args)
    lowered_aliases = lowered_alias_count(lowered.as_text())
    if lowered_aliases != expected_donated:
        findings.append(make_finding(
            "donation-mismatch", "error", path, 0,
            f"lowered program aliases {lowered_aliases} inputs, expected "
            f"{expected_donated} (theta carry leaves)",
            context=f"{name}:lowered"))

    audit = ProgramAudit(name=name, findings=findings, eqns=eqns,
                         donated_inputs=lowered_aliases)
    if not compile_program:
        return audit

    compiled = lowered.compile()
    ctext = compiled.as_text()
    pairs = compiled_alias_pairs(ctext)
    outputs = entry_output_arity(ctext)
    audit.aliased_outputs = len(pairs)
    audit.outputs = outputs if outputs is not None else -1
    if len(pairs) != expected_donated:
        findings.append(make_finding(
            "donation-mismatch", "error", path, 0,
            f"compiled executable aliases {len(pairs)} outputs, expected "
            f"{expected_donated}",
            context=f"{name}:compiled"))
    if outputs is not None:
        audit.fetch_leaves = outputs - len(pairs)
        if (expected_fetch_leaves is not None
                and audit.fetch_leaves != expected_fetch_leaves):
            findings.append(make_finding(
                "fetch-contract", "error", path, 0,
                f"{audit.fetch_leaves} non-aliased outputs, contract pins "
                f"{expected_fetch_leaves} stacked fetch leaves",
                context=f"{name}:fetch"))

    audit.transfers = host_transfer_counts(ctext)
    for op in ("outfeed", "infeed", "send", "recv", "host_callback"):
        if audit.transfers.get(op, 0):
            findings.append(make_finding(
                "host-transfer-in-program", "error", path, 0,
                f"{audit.transfers[op]} '{op}' op(s) in the compiled round "
                f"program — data may only leave through the stacked fetch",
                context=f"{name}:{op}"))
    return audit
