"""Static analysis for the round programs and the source tree.

Layer 1 (``jaxpr_audit`` / ``programs`` / ``budgets``) proves invariants on
the lowered RoundRunner programs — no f64, no host callbacks, donation
applied, one stacked fetch — and pins transfer/compile-count budgets under
``analysis/budgets/``.  Layer 2 (``lints``) is the repo-specific AST rule
pass with a justification-enforcing suppression baseline.  Entry point:
``python -m repro.analysis`` (see ``cli.py``).
"""
from .findings import Baseline, Finding, Report, make_finding  # noqa: F401
from .jaxpr_audit import (CALLBACK_PRIMITIVES, ProgramAudit,  # noqa: F401
                          audit_fn, find_callbacks, find_dtypes, iter_eqns)
