"""InternVL2-26B [arXiv:2404.16821]: InternViT-6B vision encoder + InternLM2
language model.  The vision tower + MLP projector are stubbed: input_specs()
supplies precomputed patch embeddings (batch, 256, d_model) — the allowed
modality carve-out.  This config is the InternLM2-20B language backbone."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    n_prefix_tokens=256,      # ViT patch embeddings per image
    cut_layer=12,
    source="arXiv:2404.16821",
)
