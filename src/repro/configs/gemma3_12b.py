"""Gemma3-12B [hf:google/gemma-3-1b-pt family card]: dense decoder with
5 local(SWA-1024) : 1 global attention pattern, 128k context, head_dim 256,
qk-norm.  Sub-quadratic via the 5:1 SWA pattern -> long_500k is run."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    global_every=6,           # every 6th layer global => 5:1 local:global
    cut_layer=12,
    source="hf:google/gemma-3-1b-pt (family card, 12B variant)",
)
