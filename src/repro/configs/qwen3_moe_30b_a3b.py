"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: MoE decoder, 128 experts top-8,
GQA kv=4, qk-norm, expert FFN width 768."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                 # expert FFN width (no dense MLP layers)
    vocab=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_expert=768,
    cut_layer=12,
    source="hf:Qwen/Qwen3-30B-A3B",
)
