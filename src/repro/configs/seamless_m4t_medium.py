"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder transformer
backbone.  The audio frontend (mel-spectrogram + conformer feature
extractor) is stubbed: input_specs() supplies precomputed frame embeddings
of shape (batch, frames, d_model) — the allowed modality carve-out."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    n_layers=12,              # decoder layers
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    cut_layer=3,
    source="arXiv:2308.11596",
)
