"""xLSTM-1.3B [arXiv:2405.04517]: 48 blocks, mLSTM with sLSTM interleaved
7:1, 4 heads, no separate MLP (d_ff=0 — the mLSTM block carries its own
2x up-projection)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_every=8,            # 7 mLSTM : 1 sLSTM
    cut_layer=12,
    source="arXiv:2405.04517",
)
