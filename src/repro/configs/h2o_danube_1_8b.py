"""H2O-Danube-1.8B [arXiv:2401.16818]: llama+mistral mix with sliding-window
attention (4096), GQA kv=8.  Sub-quadratic via SWA -> long_500k is run."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,
    cut_layer=6,
    source="arXiv:2401.16818",
)
