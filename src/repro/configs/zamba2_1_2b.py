"""Zamba2-1.2B [arXiv:2411.15242]: hybrid Mamba2 backbone with shared
attention blocks interleaved every 6 SSM layers (see DESIGN.md for the
weight-tying simplification)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    n_layers=38,              # mamba2 layers
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,            # MHA inside the shared attention block
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    attn_every=6,
    cut_layer=10,
    source="arXiv:2411.15242",
)
