"""DeepSeek-V2-Lite (16B) [arXiv:2405.04434]: MLA attention with kv_lora=512
compressed cache, MoE with 2 shared + 64 routed experts top-6, first layer
dense."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,               # dense-layer FFN width (layer 0)
    vocab=102400,
    kv_lora_rank=512,
    rope_dim=64,
    n_experts=64,
    top_k=6,
    d_expert=1408,
    n_shared_experts=2,
    first_dense=1,
    cut_layer=7,
    source="arXiv:2405.04434",
)
