"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family card]: dense decoder, GQA with
QKV bias, no qk-norm, full attention (long_500k skipped — see DESIGN.md)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=1_000_000.0,
    cut_layer=12,
    source="hf:Qwen/Qwen2.5-0.5B (family card, 14B variant)",
)
