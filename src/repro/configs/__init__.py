"""Config registry: ``get_config(arch_id)`` / ``list_archs()``.

One module per assigned architecture; every config cites its source
paper/model-card.  ``reduce_config`` produces the CPU-runnable smoke variant
of the same family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig, reduce_config

_ARCHS = [
    "qwen2_5_14b",
    "qwen3_moe_30b_a3b",
    "zamba2_1_2b",
    "seamless_m4t_medium",
    "xlstm_1_3b",
    "gemma3_12b",
    "internvl2_26b",
    "qwen3_8b",
    "h2o_danube_1_8b",
    "deepseek_v2_lite_16b",
]

_ALIAS = {
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "xlstm-1.3b": "xlstm_1_3b",
    "gemma3-12b": "gemma3_12b",
    "internvl2-26b": "internvl2_26b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
}


def list_archs() -> List[str]:
    return list(_ALIAS.keys())


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch).replace("-", "_").replace(".", "_")
    if mod_name not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return reduce_config(get_config(arch))


__all__ = ["get_config", "get_smoke_config", "list_archs", "ModelConfig",
           "reduce_config"]
