"""Selection policies: pluggable cluster-acceptance scoring (Section III-C
and beyond).

A :class:`SelectionPolicy` owns the *score* and *eligibility* stages of the
round-acceptance cascade (score -> rank -> verify -> commit).  The stages are
pure ``jnp`` functions of a :class:`ScoreContext`, so one policy object
serves every execution form: the fused on-device cascade compiled into the
:class:`~repro.core.runner.RoundRunner`'s round program (both placements, and
vmapped once more by the multi-seed sweep), and the host-side reference
selector (``repro.selection.selector``) used by the sequential oracle and the
param-tamper fallback.

Registered policies (``selection=`` on every protocol driver):

  * ``argmin``           — the paper's rule: argmin shared-set validation
                           loss.  The bit-identical default.
  * ``median_of_means``  — shard the shared set D_o into ``shards`` equal
                           slices and score each cluster by the *median* of
                           its per-shard mean losses: a few poisoned/outlier
                           validation samples cannot drag a cluster's score.
  * ``loss_plus_distance`` — validation-loss z-score composited with the
                           cluster's worst activation-message anomaly
                           (within-batch dispersion collapse = replay;
                           support residual = stealth noise blends), both
                           robust-z-scored across the round's clients.
                           Targets the stealth/replay families that evade
                           pure loss argmin (robustness-matrix finding).
  * ``trimmed``          — drop clusters whose validation loss is a robust
                           z-score outlier (|z| > ``z_tol``) before argmin;
                           a suspiciously *low* loss no longer wins outright.

Scores follow the loss convention: lower is better.  Ineligible clusters are
never visited by the verify cascade and can never be selected (unless every
cluster is ineligible, which falls back to all-eligible).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp

# indices into the message-stats lane of ScoreContext (see
# repro.core.split.MESSAGE_STAT_NAMES)
_STAT_DISPERSION = 0
_STAT_SUPPORT = 1


def _median(x: jnp.ndarray, axis=None, keepdims: bool = False) -> jnp.ndarray:
    """Sort-based median.  ``jnp.median`` routes through ``jnp.quantile``,
    whose fractional-index arithmetic traces float64 eqns under x64; picking
    the two middle order statistics directly keeps the program f32-pure
    (and 0.5 * (lo + hi) is bit-identical to quantile interpolation at 0.5)."""
    if axis is None:
        s = jnp.sort(x.reshape(-1))
        n = s.shape[0]
        m = jnp.float32(0.5) * (s[(n - 1) // 2] + s[n // 2])
        return jnp.reshape(m, (1,) * x.ndim) if keepdims else m
    s = jnp.sort(x, axis=axis)
    n = s.shape[axis]
    lo = jax.lax.index_in_dim(s, (n - 1) // 2, axis, keepdims=True)
    hi = jax.lax.index_in_dim(s, n // 2, axis, keepdims=True)
    m = jnp.float32(0.5) * (lo + hi)
    return m if keepdims else jnp.squeeze(m, axis)


@dataclasses.dataclass(frozen=True)
class ScoreContext:
    """Per-round features a policy may score.  ``vlosses`` is always present;
    the optional features are populated only when the policy declares it
    needs them (``shard_count`` / ``needs_message_stats``), so the default
    argmin round program carries no extra compute."""
    vlosses: jnp.ndarray                          # (R,) shared-set val losses
    shard_losses: Optional[jnp.ndarray] = None    # (R, K) per-shard losses
    message_stats: Optional[jnp.ndarray] = None   # (R, M_bar, S) train-message stats


def robust_z(x: jnp.ndarray, axis=None, eps: float = 1e-6) -> jnp.ndarray:
    """Median/MAD z-score (1.4826 * MAD estimates sigma under normality).
    ``eps`` keeps degenerate all-equal features at z = 0 instead of NaN."""
    x = x.astype(jnp.float32)
    med = _median(x, axis=axis, keepdims=axis is not None)
    mad = _median(jnp.abs(x - med), axis=axis, keepdims=axis is not None)
    return (x - med) / (jnp.float32(1.4826) * mad + jnp.float32(eps))


@dataclasses.dataclass(frozen=True)
class SelectionPolicy:
    """Base policy: argmin validation loss (the paper's Section III-C rule).

    Subclasses override :meth:`score` (lower = better) and/or
    :meth:`eligible`, plus the feature-requirement properties.  Frozen
    dataclasses: policy objects are hashable and cache as compiled-program
    keys (``repro.core.runner.protocol_runner``)."""
    name: str = "argmin"

    # -- feature requirements (drive what the round program computes) -------
    @property
    def shard_count(self) -> int:
        """> 0: the round program validates in this many D_o shards
        (requires the RoundSpec's ``validate_sharded`` hook)."""
        return 0

    @property
    def needs_message_stats(self) -> bool:
        """True: the round program surfaces per-client transmitted-message
        statistics from the training phase (``with_stats`` train programs)."""
        return False

    # -- the score / eligibility stages --------------------------------------
    def score(self, ctx: ScoreContext) -> jnp.ndarray:
        """(R,) f32 scores, lower = better.  Pure jnp: runs inside the
        compiled round under vmap/shard_map (features arrive pre-gathered
        across the cluster axis) and on host arrays in the reference
        selector."""
        return ctx.vlosses.astype(jnp.float32)

    def eligible(self, ctx: ScoreContext, scores: jnp.ndarray) -> jnp.ndarray:
        """(R,) bool mask of clusters the cascade may visit/select."""
        return jnp.ones(scores.shape, dtype=bool)


@dataclasses.dataclass(frozen=True)
class MedianOfMeansPolicy(SelectionPolicy):
    """Median over ``shards`` equal D_o slices of the per-shard mean loss."""
    name: str = "median_of_means"
    shards: int = 4

    @property
    def shard_count(self) -> int:
        return self.shards

    def score(self, ctx: ScoreContext) -> jnp.ndarray:
        assert ctx.shard_losses is not None, \
            f"{self.name} needs per-shard validation losses"
        return _median(ctx.shard_losses.astype(jnp.float32), axis=1)


@dataclasses.dataclass(frozen=True)
class LossPlusDistancePolicy(SelectionPolicy):
    """Bounded validation-loss z-score + ``weight`` x the cluster's worst
    activation-message anomaly.

    Anomaly per client = max(z(support residual), -z(dispersion), 0), robust
    z-scores taken across all R x M_bar clients of the round (malicious
    clients are a pigeonhole-bounded minority of *clients*, so the median is
    a safe reference even when most *clusters* are tainted).  A replayed
    message collapses dispersion (z << 0); a stealth noise blend leaves the
    honest activation support (z >> 0).  The cluster inherits its worst
    client's anomaly: one tainted member taints the cluster.

    Two guards make the composite robust at small scale, where the loss MAD
    can be tiny (huge loss z-scores) and the anomalous clients themselves
    inflate the dispersion MAD (deflated anomaly z-scores): the loss term is
    squashed through tanh(z / loss_scale), bounding its pull to (-1, 1)
    while preserving the argmin ordering among unflagged clusters, and the
    anomaly is hinged at ``margin`` so honest statistical noise (|z| ~ 1)
    contributes exactly zero — a flagged cluster cannot buy its way back
    with a low loss."""
    name: str = "loss_plus_distance"
    weight: float = 4.0
    margin: float = 1.5
    loss_scale: float = 3.0
    z_clip: float = 1e4

    @property
    def needs_message_stats(self) -> bool:
        return True

    def score(self, ctx: ScoreContext) -> jnp.ndarray:
        assert ctx.message_stats is not None, \
            f"{self.name} needs transmitted-message statistics"
        stats = ctx.message_stats.astype(jnp.float32)    # (R, M_bar, S)
        r, m_bar = stats.shape[0], stats.shape[1]
        flat = stats.reshape(r * m_bar, -1)
        z_disp = robust_z(flat[:, _STAT_DISPERSION])
        z_sup = robust_z(flat[:, _STAT_SUPPORT])
        zero = jnp.float32(0.0)
        anomaly = jnp.maximum(jnp.maximum(z_sup, -z_disp), zero)
        anomaly = jnp.clip(anomaly, zero,
                           jnp.float32(self.z_clip)).reshape(r, m_bar)
        cluster_dist = jnp.maximum(jnp.max(anomaly, axis=1)
                                   - jnp.float32(self.margin), zero)
        loss_term = jnp.tanh(robust_z(ctx.vlosses)
                             / jnp.float32(self.loss_scale))
        return loss_term + jnp.float32(self.weight) * cluster_dist


@dataclasses.dataclass(frozen=True)
class TrimmedPolicy(SelectionPolicy):
    """Argmin after dropping robust-z validation-loss outliers."""
    name: str = "trimmed"
    z_tol: float = 3.0

    def eligible(self, ctx: ScoreContext, scores: jnp.ndarray) -> jnp.ndarray:
        return jnp.abs(robust_z(ctx.vlosses)) <= jnp.float32(self.z_tol)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

SELECTION_REGISTRY: Dict[str, SelectionPolicy] = {}


def register_policy(policy: SelectionPolicy) -> SelectionPolicy:
    assert policy.name not in SELECTION_REGISTRY, \
        f"duplicate selection policy {policy.name!r}"
    SELECTION_REGISTRY[policy.name] = policy
    return policy


ARGMIN = register_policy(SelectionPolicy())
MEDIAN_OF_MEANS = register_policy(MedianOfMeansPolicy())
LOSS_PLUS_DISTANCE = register_policy(LossPlusDistancePolicy())
TRIMMED = register_policy(TrimmedPolicy())


def selection_policies() -> Dict[str, SelectionPolicy]:
    return dict(SELECTION_REGISTRY)


def resolve_policy(selection: Union[str, SelectionPolicy, None]) -> SelectionPolicy:
    """Driver-argument resolution: a registered name, a policy instance
    (possibly parameterised, e.g. ``LossPlusDistancePolicy(weight=2.0)``),
    or None (the default argmin)."""
    if selection is None:
        return ARGMIN
    if isinstance(selection, SelectionPolicy):
        return selection
    try:
        return SELECTION_REGISTRY[selection]
    except KeyError:
        raise ValueError(
            f"unknown selection policy {selection!r}; registered: "
            f"{sorted(SELECTION_REGISTRY)}") from None
