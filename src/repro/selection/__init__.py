"""Pluggable cluster-selection subsystem.

One subsystem owns the round-acceptance cascade — score -> rank -> verify ->
commit — in both its execution forms:

  * the **fused on-device cascade** (``cascade.py``) compiled into the
    RoundRunner's round program: ranks as data, handoff distances via the
    ``kernels/tamper_check`` Pallas kernel, rejection as a ``jnp.where``
    mask, one stacked host fetch per round;
  * the **host reference selector** (``selector.py``): the pre-refactor
    ``run_pigeon`` loop, used by the sequential oracle and the param-tamper
    fallback (handoff tampering consumes the protocol key per visited
    candidate, which is inherently host-sequenced).

Policies (``policies.py``) plug the score/eligibility stages; every protocol
driver accepts ``selection=`` (a registered name or a policy instance) with
``"argmin"`` the bit-identical default.
"""
from .cascade import (N_FETCH_TAIL, masked_first_accept, pack_fetch,
                      unpack_block_fetch, unpack_fetch)
from .policies import (ARGMIN, LOSS_PLUS_DISTANCE, MEDIAN_OF_MEANS,
                       SELECTION_REGISTRY, TRIMMED, LossPlusDistancePolicy,
                       MedianOfMeansPolicy, ScoreContext, SelectionPolicy,
                       TrimmedPolicy, register_policy, resolve_policy,
                       robust_z, selection_policies)
from .selector import (SelectionOutcome, effective_shards, host_score_context,
                       score_and_rank, select_host)

__all__ = [
    "SelectionPolicy", "MedianOfMeansPolicy", "LossPlusDistancePolicy",
    "TrimmedPolicy", "ScoreContext", "robust_z",
    "ARGMIN", "MEDIAN_OF_MEANS", "LOSS_PLUS_DISTANCE", "TRIMMED",
    "SELECTION_REGISTRY", "register_policy", "resolve_policy",
    "selection_policies",
    "masked_first_accept", "pack_fetch", "unpack_fetch",
    "unpack_block_fetch", "N_FETCH_TAIL",
    "SelectionOutcome", "select_host", "host_score_context", "score_and_rank",
    "effective_shards",
]
