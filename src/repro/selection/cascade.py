"""The masked on-device acceptance cascade (rank -> verify -> commit).

Section III-C's selection loop — argsort the cluster scores, walk candidates
in rank order, discard any whose parameter handoff fails the tamper check,
commit the first survivor (or roll back to theta^t if none survives) — used
to run as a host loop with one device sync per visited candidate.  Here the
whole cascade is expressed as masked array arithmetic so it compiles into
the round program: candidate ranks are *data* (``argsort``), rejection is a
``jnp.where`` mask, and the only host interaction is the single stacked
fetch of ``(val_losses, train_summary, selected, detections, accepted)`` the
drivers record into ``History``.

The cascade's decision contract matches the host reference selector
(``repro.selection.selector``) exactly:

  * candidates are visited in ascending masked-score order (ineligible
    clusters sort last via +inf and are never visited);
  * ``detections`` counts the visited candidates that failed verification
    before the accepted one — R_eligible when nothing survives;
  * ``accepted`` is False only when every eligible candidate fails, in which
    case ``selected`` still reports the rank-0 candidate (the argmin) for
    History/honesty bookkeeping while the commit keeps theta^t.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

# fetch layout: [vlosses (R,), train_summary (R,), selected, detections,
# accepted] — one f32 vector, one host sync per round.
N_FETCH_TAIL = 3


def masked_first_accept(scores: jnp.ndarray, eligible: jnp.ndarray,
                        passed: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(selected, detections, accepted) of the rank/verify/commit walk.

    ``scores``: (R,) f32, lower = better.  ``eligible``: (R,) bool policy
    mask (all-False falls back to all-True).  ``passed``: (R,) bool
    per-candidate verification verdicts (the handoff tamper check; all-True
    when verification is disabled)."""
    eligible = jnp.where(jnp.any(eligible), eligible,
                         jnp.ones_like(eligible))
    masked = jnp.where(eligible, scores.astype(jnp.float32),
                       jnp.float32(jnp.inf))
    ranks = jnp.argsort(masked)                      # stable: eligible first
    ok = (passed & eligible)[ranks]
    first = jnp.argmax(ok)                           # 0 when none pass
    accepted = jnp.any(ok)
    selected = ranks[jnp.where(accepted, first, 0)].astype(jnp.int32)
    detections = jnp.where(accepted, first,
                           jnp.sum(eligible)).astype(jnp.int32)
    return selected, detections, accepted


def pack_fetch(vlosses: jnp.ndarray, train_summary: jnp.ndarray,
               selected: jnp.ndarray, detections: jnp.ndarray,
               accepted: jnp.ndarray) -> jnp.ndarray:
    """Stack the round's host-visible outcome into one (2R + 3,) f32 vector
    so the drivers pay exactly one device->host sync per round."""
    tail = jnp.stack([selected, detections, accepted]).astype(jnp.float32)
    return jnp.concatenate([vlosses.astype(jnp.float32),
                            train_summary.astype(jnp.float32), tail])


def unpack_fetch(fetched, r: int):
    """Host-side view of :func:`pack_fetch` (``fetched`` already a numpy
    array): (vlosses, train_summary, selected, detections, accepted)."""
    assert fetched.shape[-1] == 2 * r + N_FETCH_TAIL
    return (fetched[:r], fetched[r:2 * r], int(fetched[2 * r]),
            int(fetched[2 * r + 1]), bool(fetched[2 * r + 2]))


def unpack_block_fetch(fetched, r: int):
    """Per-round views of a stacked ``(K, 2R+3)`` round-block fetch (K
    scanned rounds, ONE host sync): yields one :func:`unpack_fetch` tuple per
    scanned round, in round order.  Row i is bit-identical to the
    :func:`pack_fetch` vector round ``t0 + i`` would have fetched on its own
    — the scan body IS the per-round accept program."""
    assert fetched.ndim == 2, f"block fetch must be (K, 2R+3), got {fetched.shape}"
    for row in fetched:
        yield unpack_fetch(row, r)
