"""Host-side reference selector: the Section III-C acceptance loop.

This is the argmin/tamper-check/rollback cascade that used to live inline in
``core/protocol.py::run_pigeon``, lifted verbatim and generalised over a
:class:`~repro.selection.policies.SelectionPolicy`.  It remains the
*reference* execution form — the sequential oracle always runs it, and the
batched engine falls back to it whenever the threat model contains
param-tampering families (the handoff tampering and its key splits are
host-side by design: the number of key splits depends on which candidates
the cascade visits, which the fused on-device cascade cannot reproduce
without a sync).  The default batched path runs the equivalent fused cascade
compiled into the round program (``repro.selection.cascade`` via
``RoundRunner.accept``); the equivalence suite pins the two together.

Bit-compatibility: with the default argmin policy this function consumes the
numpy/JAX streams, mutates the CommMeter and walks candidates exactly as the
pre-refactor inline loop did.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .policies import ScoreContext, SelectionPolicy

# NOTE: repro.core imports are deferred into the function bodies —
# core/protocol.py imports this subsystem at module level, so importing the
# core package here would be circular.

Pytree = Any


@dataclasses.dataclass
class SelectionOutcome:
    """One round's acceptance verdict, identical in content to the fused
    cascade's single fetch (``repro.selection.cascade.pack_fetch``)."""
    selected: int
    accepted: bool
    detections: int
    theta: Tuple[Pytree, Pytree]
    scores: np.ndarray


def effective_shards(k: int, d_o: int) -> int:
    """Largest divisor of D_o at most ``k`` — the shard count both the host
    and fused median-of-means paths actually use (one shared divisor rule:
    ``repro.kernels.ops.largest_divisor``, which the tamper kernel's grid
    tiling uses as well)."""
    from ..kernels.ops import largest_divisor
    return largest_divisor(d_o, k)


@lru_cache(maxsize=None)
def _shard_loss_fn(module, k: int):
    """Jitted (phi, vacts, y0) -> (k,) per-shard shared-set losses — the
    same shard arithmetic the fused specs compile
    (``repro.core.runner.sharded_validation_losses``), applied to the
    validation-time activations the cluster already pushed."""
    from ..core.runner import sharded_validation_losses

    @jax.jit
    def f(phi, vacts, y0):
        return sharded_validation_losses(module, phi, vacts, y0, k)

    return f


def _result_vacts(module, res: Dict[str, Any], x0):
    """A result's validation-time activations, recomputed from the cluster
    params when the round body dropped them (SplitFed's batched rounds keep
    val_aux None — there is no tamper check to feed)."""
    from ..core.protocol import res_params, res_vacts
    stacked = res.get("_stacked")
    if "vacts" in res or (stacked is not None and stacked[2] is not None):
        return res_vacts(res)
    from ..core.validation import handoff_activations
    return handoff_activations(module, res_params(res)[0], x0)


def host_score_context(policy: SelectionPolicy, module,
                       results: List[Dict[str, Any]], x0, y0) -> ScoreContext:
    """Assemble the policy's feature context from host-side round results.
    Results carry ``vloss`` always, ``msg_stats`` when the round was trained
    with message statistics, and validation activations (``res_vacts``, or
    recomputed from the cluster params) for the shard-loss feature."""
    from ..core.protocol import res_params
    vlosses = jnp.asarray(np.asarray([res["vloss"] for res in results],
                                     dtype=np.float32))
    shard_losses = None
    if policy.shard_count > 0:
        x0, y0 = jnp.asarray(x0), jnp.asarray(y0)
        k = effective_shards(policy.shard_count, int(y0.shape[0]))
        fn = _shard_loss_fn(module, k)
        shard_losses = jnp.stack([
            fn(res_params(res)[1], _result_vacts(module, res, x0), y0)
            for res in results])
    message_stats = None
    if policy.needs_message_stats:
        message_stats = jnp.asarray(np.stack(
            [np.asarray(res["msg_stats"]) for res in results]))
    return ScoreContext(vlosses=vlosses, shard_losses=shard_losses,
                        message_stats=message_stats)


def score_and_rank(policy: SelectionPolicy, ctx: ScoreContext
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(scores, eligibility, visit order).  The float64 cast before argsort
    reproduces the pre-refactor host loop bit-for-bit under the argmin
    policy (it sorted the python-float loss list, i.e. a float64 array)."""
    scores = np.asarray(policy.score(ctx), dtype=np.float64)
    elig = np.asarray(policy.eligible(ctx, jnp.asarray(scores,
                                                       dtype=jnp.float32)))
    if not elig.any():
        elig = np.ones_like(elig)
    order = np.argsort(scores)
    return scores, elig, order


def select_host(policy: SelectionPolicy, module, results: List[Dict[str, Any]],
                theta: Tuple[Pytree, Pytree], tm, t: int, key: jax.Array,
                pcfg, meter, x0, y0, d_c: int
                ) -> Tuple[jax.Array, SelectionOutcome]:
    """The reference cascade: rank by policy score, visit candidates in
    order, tamper-check each handoff (rolling the protocol key exactly when
    the visited candidate's last client mounts a handoff attack), commit the
    first survivor.  Mutates ``meter`` with the per-visit re-transmission
    accounting (Table I's 2R*D_o validation term)."""
    from ..core import attacks as atk
    from ..core.protocol import (account_handoff_recheck, res_params,
                                 res_vacts)
    from ..core.validation import check_handoff, handoff_activations
    ctx = host_score_context(policy, module, results, x0, y0)
    scores, elig, order = score_and_rank(policy, ctx)
    d_o = int(x0.shape[0])

    detection_events = 0
    selected: Optional[int] = None
    new_theta = theta
    for cand in order:
        if not elig[cand]:
            continue                  # trimmed outlier: never visited
        res = results[cand]
        last_client = res["cluster"][-1]
        g_sel, p_sel = res_params(res)
        handed = g_sel
        pt = tm.param_attack_for(last_client, t)
        if pt is not None:
            key, sub = jax.random.split(key)
            handed = atk.tamper_params(pt, g_sel, sub)
        if pcfg.tamper_check:
            # next-round first clients re-transmit g(x0, gamma_received);
            # >=1 of the R recipients is honest, so a tampered handoff is
            # always visible against the validation-time activations.
            recv = handoff_activations(module, handed, x0)
            account_handoff_recheck(meter, pcfg, d_o, d_c, visited=1)
            ok, dist = check_handoff(res_vacts(res), [recv], pcfg.tamper_tol)
            if not ok:
                detection_events += 1
                continue              # discard tampered cluster, reselect
        selected = int(cand)
        new_theta = (handed, p_sel)
        break

    accepted = selected is not None
    if not accepted:                  # every candidate tampered: keep theta^t
        selected = int(next(c for c in order if elig[c]))
        new_theta = theta
    return key, SelectionOutcome(selected=selected, accepted=accepted,
                                 detections=detection_events,
                                 theta=new_theta,
                                 scores=scores.astype(np.float32))
