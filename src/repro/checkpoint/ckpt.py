"""Checkpointing: pytree <-> .npz + JSON treedef (no orbax dependency).

Arrays are flattened with ``jax.tree_util.tree_flatten_with_path`` so the archive keys
are stable, human-readable paths; restore rebuilds the exact pytree
structure.  Works for params, optimizer states and protocol state alike.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Pytree, metadata: Optional[Dict] = None) -> None:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {f"a{i}": np.asarray(v) for i, (_, v) in enumerate(flat)}
    names = [_path_str(p) for p, _ in flat]
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path + ".npz", **arrays)
    meta = {"names": names, "treedef": str(treedef), "metadata": metadata or {}}
    with open(path + ".json", "w") as f:
        json.dump(meta, f)


def load_checkpoint(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Returns ({path_name: array}, metadata)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    with np.load(path + ".npz") as z:
        arrays = {meta["names"][int(k[1:])]: z[k] for k in z.files}
    return arrays, meta.get("metadata", {})


def restore_pytree(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (shapes must match)."""
    arrays, _ = load_checkpoint(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, v in flat:
        name = _path_str(p)
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = arrays[name]
        if tuple(a.shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch at {name}: {a.shape} vs {v.shape}")
        out.append(jax.numpy.asarray(a, dtype=v.dtype))
    return jax.tree.unflatten(treedef, out)
