"""Checkpointing: pytree <-> .npz + JSON treedef (no orbax dependency).

Arrays are flattened with ``jax.tree_util.tree_flatten_with_path`` so the archive keys
are stable, human-readable paths; restore rebuilds the exact pytree
structure.  Works for params, optimizer states and protocol state alike.

Durability contract: :func:`save_checkpoint` is crash-atomic.  Both files are
written to temp files in the target directory and moved into place with
``os.replace``, arrays first and the ``.json`` manifest last, and the two
halves share a random token — so a reader either sees a complete consistent
checkpoint or detects the tear (:class:`CorruptCheckpointError`) instead of
half-loading it.

The module also snapshots/restores the protocol's two randomness streams
(:func:`protocol_state_metadata` / :func:`restore_protocol_state`) so
``run_pigeon(resume=True)`` stays *on-stream*: a resumed run consumes the
numpy RNG and the JAX key exactly where the uninterrupted run would.
"""
from __future__ import annotations

import json
import os
import tempfile
import zipfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


class CorruptCheckpointError(RuntimeError):
    """The manifest and array halves do not form one consistent save (torn
    write from a pre-atomic-era crash, truncation, or bit rot)."""


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _atomic_write(path: str, write_fn) -> None:
    """Write via a temp file in the same directory + ``os.replace`` so the
    final name only ever points at complete content."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp.")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_checkpoint(path: str, tree: Pytree, metadata: Optional[Dict] = None) -> None:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {f"a{i}": np.asarray(v) for i, (_, v) in enumerate(flat)}
    names = [_path_str(p) for p, _ in flat]
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # the token ties the two files to one save; mismatch => torn checkpoint
    token = os.urandom(8).hex()
    arrays["__token__"] = np.array(token)
    _atomic_write(path + ".npz", lambda f: np.savez(f, **arrays))
    meta = {"names": names, "treedef": str(treedef), "token": token,
            "metadata": metadata or {}}
    _atomic_write(path + ".json", lambda f: f.write(json.dumps(meta).encode()))


def load_checkpoint(path: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Returns ({path_name: array}, metadata).  Raises ``FileNotFoundError``
    if either half is missing and :class:`CorruptCheckpointError` if the
    halves are unreadable or belong to different saves."""
    try:
        with open(path + ".json") as f:
            meta = json.load(f)
    except json.JSONDecodeError as e:
        raise CorruptCheckpointError(
            f"unreadable checkpoint manifest {path}.json: {e}") from e
    try:
        with np.load(path + ".npz", allow_pickle=False) as z:
            token = str(z["__token__"]) if "__token__" in z.files else None
            arrays = {meta["names"][int(k[1:])]: z[k]
                      for k in z.files if k != "__token__"}
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, OSError, ValueError, KeyError, IndexError) as e:
        raise CorruptCheckpointError(
            f"unreadable checkpoint arrays {path}.npz: {e}") from e
    manifest_token = meta.get("token")
    # equal-None = legacy pre-token checkpoint (allowed); one-sided or
    # mismatched tokens = halves from different saves
    if token != manifest_token:
        raise CorruptCheckpointError(
            f"torn checkpoint at {path}: manifest token {manifest_token!r} != "
            f"arrays token {token!r} (the two halves come from different "
            f"saves)")
    return arrays, meta.get("metadata", {})


def restore_pytree(path: str, like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (shapes must match)."""
    arrays, _ = load_checkpoint(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, v in flat:
        name = _path_str(p)
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = arrays[name]
        if tuple(a.shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch at {name}: {a.shape} vs {v.shape}")
        out.append(jax.numpy.asarray(a, dtype=v.dtype))
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# protocol randomness-stream snapshots (the on-stream resume contract)
# ---------------------------------------------------------------------------

def _is_typed_key(key) -> bool:
    try:
        return jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def protocol_state_metadata(rng: np.random.Generator, key) -> Dict[str, Any]:
    """JSON-serializable snapshot of the protocol's two randomness streams:
    the numpy bit-generator state (clustering + mini-batch sampling) and the
    JAX key (per-round/client splits, tamper-check splits).  Stored in the
    checkpoint metadata so resume replays *state*, not draws."""
    raw = jax.random.key_data(key) if _is_typed_key(key) else key
    return {"rng_state": rng.bit_generator.state,
            "key": np.asarray(raw).astype(np.uint32).tolist()}


def restore_protocol_state(rng: np.random.Generator, key_like,
                           metadata: Dict[str, Any]):
    """Inverse of :func:`protocol_state_metadata`: mutates ``rng`` in place
    and returns the restored key (typed iff ``key_like`` is typed)."""
    rng.bit_generator.state = metadata["rng_state"]
    raw = jnp.asarray(np.asarray(metadata["key"], dtype=np.uint32))
    if _is_typed_key(key_like):
        return jax.random.wrap_key_data(raw)
    return raw


def job_checkpoint_metadata(t: int, stream_snap: Dict[str, Any],
                            job: Optional[str] = None) -> Dict[str, Any]:
    """Checkpoint metadata for one protocol run's round ``t``: the round
    index + randomness-stream snapshot the solo driver stores, plus (for
    pool-scheduled jobs) the owning job's name — the snapshot layout is
    byte-compatible with a solo run's, so a job checkpointed under the pool
    resumes under the solo driver and vice versa."""
    meta = {"round": t, **stream_snap}
    if job is not None:
        meta["job"] = job
    return meta
