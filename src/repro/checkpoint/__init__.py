from .ckpt import (CorruptCheckpointError, load_checkpoint,
                   protocol_state_metadata, restore_protocol_state,
                   restore_pytree, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "restore_pytree",
           "CorruptCheckpointError", "protocol_state_metadata",
           "restore_protocol_state"]
