from .ckpt import (CorruptCheckpointError, job_checkpoint_metadata,
                   load_checkpoint, protocol_state_metadata,
                   restore_protocol_state, restore_pytree, save_checkpoint)

__all__ = ["save_checkpoint", "load_checkpoint", "restore_pytree",
           "CorruptCheckpointError", "protocol_state_metadata",
           "restore_protocol_state", "job_checkpoint_metadata"]
