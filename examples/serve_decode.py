"""Batched serving demo: KV-cache decode across architecture families.

    PYTHONPATH=src python examples/serve_decode.py

Greedy-decodes batched prompts through smoke-scale variants of three
families (dense GQA, Mamba2 hybrid, MLA+MoE) — the same ``serve_step`` the
dry-run lowers for decode_32k / long_500k on the production mesh.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data import make_markov_tokens
from repro.models import build_model


def decode_demo(arch: str, batch=4, prompt_len=12, new_tokens=20):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(batch, prompt_len + new_tokens)
    prompts = make_markov_tokens(0, cfg.vocab, batch, prompt_len)
    decode = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i),
                     donate_argnums=(1,))
    t0 = time.time()
    logits = None
    for i in range(prompt_len):
        logits, cache = decode(params, cache, jnp.asarray(prompts[:, i:i+1]), i)
    toks = []
    for j in range(new_tokens):
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks.append(np.asarray(nxt))
        logits, cache = decode(params, cache, nxt, prompt_len + j)
    dt = time.time() - t0
    rate = batch * (prompt_len + new_tokens) / dt
    print(f"{arch:24s} [{cfg.arch_type:6s}] {rate:8.1f} tok/s  "
          f"sample: {np.concatenate(toks,1)[0][:10].tolist()}")


def main():
    for arch in ("qwen3-8b", "zamba2-1.2b", "deepseek-v2-lite-16b"):
        decode_demo(arch)


if __name__ == "__main__":
    main()
