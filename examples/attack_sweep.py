"""Attack sweep: all three paper attacks x {vanilla SL, Pigeon-SL,
Pigeon-SL+}, printing a compact result matrix (a fast, reduced version of
the Fig. 3 benchmark).

    PYTHONPATH=src python examples/attack_sweep.py
"""
from repro.core import (ACTIVATION, GRADIENT, LABEL_FLIP, Attack,
                        ProtocolConfig, from_cnn, run_pigeon, run_vanilla_sl)
from repro.data import build_image_task


def main():
    data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=300, d_o=150,
                                     n_test=800, seed=0)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=4, N=1, T=5, E=5, B=32, lr=0.05, seed=0)
    malicious = {1}

    print(f"{'attack':12s} {'vanilla':>8s} {'pigeon':>8s} {'pigeon+':>8s}")
    for name, kind in [("label_flip", LABEL_FLIP), ("activation", ACTIVATION),
                       ("gradient", GRADIENT)]:
        attack = Attack(kind)
        a_v = run_vanilla_sl(module, data, pcfg, malicious, attack
                             ).rounds[-1]["test_acc"]
        a_p = run_pigeon(module, data, pcfg, malicious, attack
                         ).rounds[-1]["test_acc"]
        a_pp = run_pigeon(module, data, pcfg, malicious, attack, plus=True
                          ).rounds[-1]["test_acc"]
        print(f"{name:12s} {a_v:8.3f} {a_p:8.3f} {a_pp:8.3f}")


if __name__ == "__main__":
    main()
