"""Attack sweep: the three paper attacks plus a heterogeneous mixed
population x {vanilla SL, Pigeon-SL, Pigeon-SL+}, printing a compact result
matrix (a fast, reduced version of the Fig. 3 / robustness-matrix
benchmarks).  The Pigeon rows run through the batched cluster-parallel
engine; the mixed row exercises the adversary subsystem's ``ThreatModel``
with one label flipper plus one Byzantine gradient scaler.  Note that any
two malicious clients exceed this config's tolerance budget (M=4, N=1), so
the pigeonhole honest-cluster guarantee does NOT hold for the mixed row —
it shows how selection degrades gracefully beyond the budget.

    PYTHONPATH=src python examples/attack_sweep.py
"""
from repro.core import (ACTIVATION, GRAD_SCALE, GRADIENT, LABEL_FLIP, Attack,
                        ProtocolConfig, ThreatModel, from_cnn, run_pigeon,
                        run_vanilla_sl)
from repro.data import build_image_task


def main():
    data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=300, d_o=150,
                                     n_test=800, seed=0)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=4, N=1, T=5, E=5, B=32, lr=0.05, seed=0)

    rows = [(name, ThreatModel.build({1: Attack(kind)}))
            for name, kind in [("label_flip", LABEL_FLIP),
                               ("activation", ACTIVATION),
                               ("gradient", GRADIENT)]]
    rows.append(("mixed", ThreatModel.build({
        1: Attack(LABEL_FLIP),
        3: Attack(GRAD_SCALE, grad_scale=6.0),
    })))

    print(f"{'threat':12s} {'vanilla':>8s} {'pigeon':>8s} {'pigeon+':>8s}")
    for name, tm in rows:
        a_v = run_vanilla_sl(module, data, pcfg, threat_model=tm
                             ).rounds[-1]["test_acc"]
        a_p = run_pigeon(module, data, pcfg, threat_model=tm,
                         engine="batched").rounds[-1]["test_acc"]
        a_pp = run_pigeon(module, data, pcfg, threat_model=tm, plus=True,
                          engine="batched").rounds[-1]["test_acc"]
        print(f"{name:12s} {a_v:8.3f} {a_p:8.3f} {a_pp:8.3f}")


if __name__ == "__main__":
    main()
