"""End-to-end driver: Pigeon-SL over a transformer language model.

    PYTHONPATH=src python examples/robust_llm_training.py [--steps-per-client 5]
        [--rounds 8] [--d-model 512] [--layers 8]

Builds a ~small decoder LM (default ~25M params; --d-model 768 --layers 12
gives ~100M — a few hours on this 1-core CPU container, minutes on real
hardware), splits it at the cut layer, and runs the full Pigeon-SL+ protocol
over Markov-chain token data with one label-flipping client.  Demonstrates
the framework integration: the SAME protocol code drives the paper's CNNs
and every assigned architecture.
"""
import argparse
import time

from repro.core import (Attack, LABEL_FLIP, ProtocolConfig, from_lm, run_pigeon)
from repro.data import build_lm_task
from repro.models import build_model
from repro.models.config import ModelConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--steps-per-client", type=int, default=4)
    ap.add_argument("--clients", type=int, default=4)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="pigeon-lm", arch_type="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128), d_ff=4 * args.d_model,
        vocab=args.vocab, cut_layer=max(1, args.layers // 4))
    model = build_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab} "
          f"(~{n_params/1e6:.1f}M params), cut at block {cfg.cut_layer}")

    module = from_lm(model)
    data = build_lm_task(vocab=cfg.vocab, seq_len=args.seq,
                         m_clients=args.clients, d_m=128, d_o=48, n_test=48)
    pcfg = ProtocolConfig(M=args.clients, N=1, T=args.rounds,
                          E=args.steps_per_client, B=8, lr=3e-2, seed=0)
    t0 = time.time()
    hist = run_pigeon(module, data, pcfg, malicious={1},
                      attack=Attack(LABEL_FLIP), plus=True, verbose=True)
    print(f"\nfinal next-token accuracy: {hist.rounds[-1]['test_acc']:.4f} "
          f"(uniform = {1/args.vocab:.4f}); wall {time.time()-t0:.0f}s")
    print("honest-cluster selections:",
          [r["selected_honest"] for r in hist.rounds])


if __name__ == "__main__":
    main()
