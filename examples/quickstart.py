"""Quickstart: Pigeon-SL vs vanilla SL with one malicious client.

    PYTHONPATH=src python examples/quickstart.py

Trains the paper's MNIST CNN (synthetic class-template data) with M=4
clients, one of them gradient-tampering, and shows Pigeon-SL+ selecting
honest clusters while vanilla SL absorbs the poisoned updates.
"""
from repro.core import (Attack, GRADIENT, ProtocolConfig, from_cnn,
                        run_pigeon, run_vanilla_sl)
from repro.data import build_image_task


def main():
    data, cnn_cfg = build_image_task("mnist", m_clients=4, d_m=300, d_o=150,
                                     n_test=1000, seed=0)
    module = from_cnn(cnn_cfg)
    pcfg = ProtocolConfig(M=4, N=1, T=6, E=5, B=32, lr=0.05, seed=0)
    malicious = {1}
    attack = Attack(GRADIENT)

    print("=== Pigeon-SL+ (robust) ===")
    hist_p = run_pigeon(module, data, pcfg, malicious, attack, plus=True,
                        verbose=True)
    print("\n=== vanilla SL (baseline) ===")
    hist_v = run_vanilla_sl(module, data, pcfg, malicious, attack, verbose=True)

    acc_p = hist_p.rounds[-1]["test_acc"]
    acc_v = hist_v.rounds[-1]["test_acc"]
    honest = sum(r["selected_honest"] for r in hist_p.rounds)
    print(f"\nfinal accuracy: pigeon+={acc_p:.3f}  vanilla={acc_v:.3f}")
    print(f"pigeon+ selected an honest cluster {honest}/{len(hist_p.rounds)} rounds")


if __name__ == "__main__":
    main()
